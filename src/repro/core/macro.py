"""DCIM macro specification, assembly, and PPA roll-up (paper §III-A/§III-D).

``MacroSpec`` is the compiler *input* (architecture parameters + performance
constraints); ``MacroDesign`` is one synthesized design point: a concrete
choice of subcircuit variants plus its rolled-up PPA.  The roll-up composes
the subcircuit models of :mod:`repro.core.subcircuits` and applies voltage and
switching-activity scaling from :mod:`repro.core.tech`.

Throughput conventions (match Table II footnotes):
  * ``tops_1b(v)``    — 2·H·W·f(v), the "scaled to 1b input / 1b weight" TOPS
  * ``macs_per_s``    — real ib×wb MAC rate: H·(W/wb)·f/ib
The silicon anchors (1.1 GHz @1.2 V -> 9.0 TOPS; 1921 TOPS/W @0.7 V; 0.112 mm²)
are reproduced by construction via :func:`calibrated_tech_for_reference`.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, replace

import numpy as np

from . import subcircuits as sc
from .csa import CSADesign, CSAReport
from .tech import TechModel

# Table II measurement conditions (used for calibration + default reporting).
ACT_IN_MEAS = 0.125    # input sparsity 12.5%
ACT_WT_MEAS = 0.5      # weight sparsity 50%


@dataclass(frozen=True)
class MacroSpec:
    """User-facing compiler input (paper Fig. 2 'Input Specifications')."""

    h: int = 64                     # rows (accumulation depth)
    w: int = 64                     # columns (1-bit weight lanes)
    mcr: int = 2                    # memory-compute ratio
    int_precisions: tuple[int, ...] = (1, 2, 4, 8)
    fp_precisions: tuple[str, ...] = ("FP4", "FP8")
    f_mac_hz: float = 800e6         # required MAC frequency
    f_wupdate_hz: float = 800e6     # required weight-update frequency
    vdd: float = 0.9                # voltage at which constraints apply
    # PPA preference weights (power, area, throughput) — §III-C "chosen based
    # on PPA preferences":
    w_power: float = 1.0
    w_area: float = 1.0
    w_throughput: float = 1.0

    def __post_init__(self):
        if self.h < 4 or self.w < 4:
            raise ValueError("macro dims must be >= 4")
        if self.h & (self.h - 1) or self.w & (self.w - 1):
            raise ValueError("macro dims must be powers of two")
        if self.mcr < 1:
            raise ValueError("MCR must be >= 1")
        if not self.int_precisions:
            raise ValueError("need at least one INT precision")
        bad = [f for f in self.fp_precisions if f not in sc.FP_FORMATS]
        if bad:
            raise ValueError(f"unknown FP formats: {bad}")

    @property
    def max_input_bits(self) -> int:
        fp_int = [sc.FP_FORMATS[f][1] + 2 for f in self.fp_precisions]
        return max(list(self.int_precisions) + fp_int)

    @property
    def array_kbit(self) -> float:
        return self.h * self.w / 1024.0


def reference_chip_spec() -> MacroSpec:
    """The fabricated 40nm test chip (paper §IV-B)."""
    return MacroSpec(h=64, w=64, mcr=2, int_precisions=(1, 2, 4, 8),
                     fp_precisions=("FP4", "FP8"), f_mac_hz=1.1e9,
                     f_wupdate_hz=1.1e9, vdd=1.2)


def pareto_experiment_spec() -> MacroSpec:
    """Fig. 8 experiment spec: H=W=64, MCR=2, INT4/8 + FP4/8, 800 MHz @0.9 V."""
    return MacroSpec(h=64, w=64, mcr=2, int_precisions=(4, 8),
                     fp_precisions=("FP4", "FP8"), f_mac_hz=800e6,
                     f_wupdate_hz=800e6, vdd=0.9)


# ---------------------------------------------------------------------------
# Design point
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MacroDesign:
    """A concrete subcircuit selection for a spec."""

    spec: MacroSpec
    memcell: sc.MemCellKind = sc.MemCellKind.SRAM_6T
    multmux: sc.MultMuxKind = sc.MultMuxKind.TG_NOR
    csa: CSADesign = CSADesign(rho=1.0)
    ofu_pipe_stages: int = 0              # tt5 (repeatable)
    ofu_retimed_into_sa: bool = False     # tt4
    fuse_tree_sa: bool = False            # Step 3 register fusion
    fuse_sa_ofu: bool = False
    # Precision provisioning (lattice "precision" axis): the weight-precision
    # set the OFU fusion chain is built for and the FP format set the
    # alignment unit is built for.  None means the spec's own lists — the
    # seed behavior, bit-identical.
    ofu_precisions: tuple[int, ...] | None = None
    align_fp: tuple[str, ...] | None = None
    # Approximate adder-tree cell (lattice "approx_cell" axis); None/exact
    # reproduces the characterized exact tree bit-for-bit.
    approx_cell: sc.ApproxCellSpec | None = None
    audit: tuple[str, ...] = ()           # searcher decision log

    def name(self) -> str:
        bits = [self.memcell.value, self.multmux.value, self.csa.name()]
        if self.approx_cell is not None and not self.approx_cell.is_exact():
            bits.append(self.approx_cell.name)
        if self.ofu_pipe_stages:
            bits.append(f"ofuP{self.ofu_pipe_stages}")
        if self.ofu_precisions:
            bits.append(f"provW{max(self.ofu_precisions)}")
        if self.align_fp:
            bits.append(f"provF{len(self.align_fp)}")
        if self.fuse_tree_sa:
            bits.append("fTS")
        if self.fuse_sa_ofu:
            bits.append("fSO")
        return "-".join(bits)

    def with_audit(self, msg: str) -> "MacroDesign":
        return replace(self, audit=self.audit + (msg,))


# ---------------------------------------------------------------------------
# PPA roll-up
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PathReport:
    mac_path_rel: float       # WL -> mult -> tree (tau)
    sa_path_rel: float
    ofu_path_rel: float
    crit_rel: float


@dataclass(frozen=True)
class MacroPPA:
    design: MacroDesign
    paths: PathReport
    fmax_hz: float                  # at spec.vdd
    area_um2: float
    area_breakdown: dict
    e_cycle_fj: dict                # mode -> per-cycle energy at spec.vdd, meas activity
    latency_cycles: int             # input-bit-0 in -> fused result out (INT max-prec)
    tops_1b: float                  # at spec.vdd, fmax
    tops_per_w_1b: dict             # mode -> 1b-scaled TOPS/W at spec.vdd
    tops_per_mm2_1b: float
    meets_timing: bool
    csa_report: CSAReport = None

    def summary(self) -> dict:
        return {
            "design": self.design.name(),
            "fmax_mhz": round(self.fmax_hz / 1e6, 1),
            "area_mm2": round(self.area_um2 / 1e6, 4),
            "tops_1b": round(self.tops_1b, 2),
            "tops_w_int_lo": round(self.tops_per_w_1b["int_lo"], 1),
            "tops_mm2": round(self.tops_per_mm2_1b, 1),
            "latency_cycles": self.latency_cycles,
            "meets_timing": self.meets_timing,
        }


def _product_bits(spec: MacroSpec) -> int:
    """Bit-serial inputs: each cycle the tree reduces H 1b x 1b products per
    column lane; signed handling adds a guard bit."""
    return 2


def reporting_frequency(fmax_hz, f_mac_hz, meets_timing):
    """The clock a deployed macro is *reported* (and served) at.

    A design that meets timing is down-clocked to the spec'd MAC frequency
    (``min(fmax, f_mac)``); a timing-missing design reports its raw ``fmax``.
    This is the single clamp shared by :func:`rollup`, the scalar
    ``dse.accelerator_report``, the batched ``dse.batched_workload_matrix``,
    the lattice engine's throughput roll-up, and multi-spec serving selection
    — so the same design is never clocked differently by different reporting
    paths.  Accepts scalars or arrays."""
    fmax_hz = np.asarray(fmax_hz, dtype=np.float64)
    f_mac_hz = np.asarray(f_mac_hz, dtype=np.float64)
    meets = np.asarray(meets_timing, dtype=bool)
    return np.where(meets, np.minimum(fmax_hz, f_mac_hz), fmax_hz)


def timing_paths(design: MacroDesign, tech: TechModel) -> tuple[PathReport, CSAReport, dict]:
    spec = design.spec
    wl = sc.wl_driver_ppa(spec.h, spec.w, spec.mcr, tech)
    mm = sc.multmux_ppa(design.multmux, spec.mcr, tech)
    tree_ppa, csa_rep = sc.adder_tree_ppa(design.csa, spec.h,
                                          _product_bits(spec), tech,
                                          cell=design.approx_cell)
    sa = sc.shift_adder_ppa(csa_rep.acc_width, spec.max_input_bits, tech)
    out_w = csa_rep.acc_width + spec.max_input_bits
    ofu = sc.ofu_ppa(spec.w,
                     design.ofu_precisions or tuple(spec.int_precisions),
                     out_w, design.ofu_pipe_stages, tech)
    align = sc.align_ppa(spec.w,
                         design.align_fp or tuple(spec.fp_precisions), tech)

    mac_path = wl.delay_rel + mm.delay_rel + tree_ppa.delay_rel
    sa_path = sa.delay_rel
    ofu_path = ofu.delay_rel
    if design.ofu_retimed_into_sa:
        moved = 0.3 * ofu_path
        ofu_path -= moved
        sa_path += moved
    if design.fuse_tree_sa:
        mac_path = mac_path + sa_path
        sa_path = 0.0
    if design.fuse_sa_ofu:
        sa_path = sa_path + ofu_path
        ofu_path = 0.0
    # The alignment unit is an input-side stage with its own (internally
    # pipelineable) registers; the paper's critical paths are "the WL driver,
    # multiplier, adder tree, and OFU" (§III-C), so align is excluded here.
    crit = max(mac_path, sa_path, ofu_path)
    parts = {"wl": wl, "multmux": mm, "tree": tree_ppa, "sa": sa, "ofu": ofu,
             "align": align}
    return PathReport(mac_path, sa_path, ofu_path, crit), csa_rep, parts


def _mode_bits(spec: MacroSpec, mode: str) -> int:
    """Bit-serial input cycles per result in a given mode."""
    if mode == "int_lo":
        return min(spec.int_precisions)
    if mode == "int_hi":
        return max(spec.int_precisions)
    exp, man = sc.FP_FORMATS[mode]
    return man + 2  # aligned mantissa (+hidden bit +sign) streams bit-serially


def _mode_energy_rel(design: MacroDesign, parts: dict, mode: str,
                     act_in: float, act_wt: float) -> float:
    """Per-cycle switching energy (eps units, at VDD_NOM) in a given mode.

    Modes: 'int_lo' (min INT), 'int_hi' (max INT), and each FP format.
    FP modes activate the alignment unit — the source of the ~+10% (FP8 vs
    INT4) and ~+20% (BF16 vs INT8) power overheads in Fig. 7.
    """
    spec = design.spec
    wl, mm, tree, sa, ofu, align = (parts["wl"], parts["multmux"],
                                    parts["tree"], parts["sa"], parts["ofu"],
                                    parts["align"])
    e = 0.0
    e += wl.energy_rel * act_in                      # rows toggle with inputs
    e += spec.h * spec.w * mm.energy_rel * act_in * act_wt
    tree_act = min(1.0, act_in * act_wt + 0.02)      # glitch floor
    e += tree.energy_rel * tree_act
    e += sa.energy_rel * 0.55                        # active every cycle
    # OFU fires once per completed bit-serial result:
    ib = _mode_bits(spec, mode)
    e += ofu.energy_rel * (0.5 / max(1, ib))
    if mode in sc.FP_FORMATS:
        # Alignment activity scales with the active format's width relative to
        # the widest format the unit was built for.
        exp, man = sc.FP_FORMATS[mode]
        built_for = design.align_fp or spec.fp_precisions
        emax = max(sc.FP_FORMATS[f][0] for f in built_for)
        mmax = max(sc.FP_FORMATS[f][1] for f in built_for)
        frac = (exp + 0.5 * man) / (emax + 0.5 * mmax)
        e += align.energy_rel * 0.62 * frac
    else:
        e += align.energy_rel * 0.04                 # clock gating residue
    # Weight update (BL drivers + SRAM write) at the spec'd update duty:
    duty = min(1.0, spec.f_wupdate_hz / max(spec.f_mac_hz, 1.0)) * 1.0 / (spec.h * spec.mcr)
    # (one row re-written per update event)
    bl = sc.bl_driver_ppa(spec.h, spec.w, spec.mcr, TechModel())  # rel consts only
    e += (bl.energy_rel / (spec.h * spec.mcr)) * duty
    return e


def rollup(design: MacroDesign, tech: TechModel,
           act_in: float = ACT_IN_MEAS, act_wt: float = ACT_WT_MEAS) -> MacroPPA:
    spec = design.spec
    paths, csa_rep, parts = timing_paths(design, tech)
    fmax = tech.fmax_hz(paths.crit_rel, spec.vdd)
    meets = fmax >= spec.f_mac_hz * 0.999

    # ---- area ---------------------------------------------------------------
    cell = sc.memcell_ppa(design.memcell, tech)
    n_cells = spec.h * spec.w * spec.mcr
    a_array = n_cells * cell.area_um2
    a_mult = spec.h * spec.w * parts["multmux"].area_um2
    a_tree = parts["tree"].area_um2 * spec.w
    a_sa = parts["sa"].area_um2 * spec.w
    a_ofu = parts["ofu"].area_um2
    a_align = parts["align"].area_um2
    a_drv = (sc.wl_driver_ppa(spec.h, spec.w, spec.mcr, tech).area_um2
             + sc.bl_driver_ppa(spec.h, spec.w, spec.mcr, tech).area_um2)
    breakdown = {"sram_array": a_array, "multmux": a_mult, "adder_tree": a_tree,
                 "shift_adder": a_sa, "ofu": a_ofu, "align": a_align,
                 "drivers": a_drv}
    area = sum(breakdown.values()) * tech.apr_overhead

    # ---- per-cycle energy by mode --------------------------------------------
    # Tree/S&A energies above are per *column*; scale to W columns here.
    parts_scaled = dict(parts)
    parts_scaled["tree"] = parts["tree"].scaled(k_energy=spec.w)
    parts_scaled["sa"] = parts["sa"].scaled(k_energy=spec.w)
    modes = ["int_lo", "int_hi"] + list(spec.fp_precisions)
    e_cycle = {}
    for m in modes:
        rel = _mode_energy_rel(design, parts_scaled, m, act_in, act_wt)
        e_cycle[m] = tech.energy_fj(rel, spec.vdd)

    # ---- latency --------------------------------------------------------------
    ib = max(spec.int_precisions)
    pipe = csa_rep.latency_cycles + parts["sa"].latency_cycles + parts["ofu"].latency_cycles
    if design.fuse_tree_sa:
        pipe -= 1
    if design.fuse_sa_ofu:
        pipe -= 1
    latency = ib + max(1, pipe)

    # ---- throughput -------------------------------------------------------------
    f_rep = float(reporting_frequency(fmax, spec.f_mac_hz, meets))
    tops_1b = 2.0 * spec.h * spec.w * f_rep / 1e12
    leak_mw = tech.leakage_mw(area, spec.vdd)
    tops_w = {}
    for m, efj in e_cycle.items():
        p_mw = efj * 1e-15 * f_rep * 1e3 + leak_mw
        tops_w[m] = tops_1b / (p_mw * 1e-3) if p_mw > 0 else float("inf")
    tops_mm2 = tops_1b / (area / 1e6)

    return MacroPPA(design=design, paths=paths, fmax_hz=fmax, area_um2=area,
                    area_breakdown=breakdown, e_cycle_fj=e_cycle,
                    latency_cycles=latency, tops_1b=tops_1b,
                    tops_per_w_1b=tops_w, tops_per_mm2_1b=tops_mm2,
                    meets_timing=meets, csa_report=csa_rep)


# ---------------------------------------------------------------------------
# Calibration against the test chip
# ---------------------------------------------------------------------------


def reference_chip_design() -> MacroDesign:
    """The silicon-validated design point: mixed CSA with reordering and a
    retimed final RCA (paper §III-B + §IV-B)."""
    return MacroDesign(spec=reference_chip_spec(),
                       memcell=sc.MemCellKind.SRAM_6T,
                       multmux=sc.MultMuxKind.TG_NOR,
                       csa=CSADesign(rho=0.5, reorder=True, retimed=True),
                       ofu_pipe_stages=1,
                       fuse_sa_ofu=False)


@functools.lru_cache(maxsize=1)
def calibrated_tech_for_reference() -> TechModel:
    """Solve (tau, eps, apr) so the reference design reproduces the measured
    silicon exactly (see tech.py anchors).  Three-step, deterministic:

      1. tau  <- 1.1 GHz @ 1.2 V on the reference critical path;
      2. apr  <- 0.112 mm^2 on the reference placed area;
      3. eps  <- 1921 TOPS/W @ 0.7 V *after* subtracting leakage of the
                 calibrated area (leakage is ~5% at 0.7 V — ignoring it would
                 bias the dynamic-energy unit).
    """
    from . import tech as T

    base = TechModel()
    ref = reference_chip_design()
    paths, _csa, parts = timing_paths(ref, base)

    # Step 1: delay unit.
    tau = (1e12 / T.F_ANCHOR_HZ) / (paths.crit_rel * T.delay_scale(T.V_ANCHOR))

    # Step 2: area unit (APR/routing overhead multiplier).
    ppa0 = rollup(ref, base)
    apr = T.AREA_ANCHOR_UM2 / ppa0.area_um2

    # Step 3: energy unit at the Table II operating point (0.7 V).
    f_low = 1e12 / (paths.crit_rel * tau * T.delay_scale(T.V_LOW))
    tops_low = 2.0 * ref.spec.h * ref.spec.w * f_low / 1e12
    p_target_mw = tops_low / T.EEFF_ANCHOR_TOPS_W * 1e3          # W -> mW
    leak_mw = (T.AREA_ANCHOR_UM2 * base.leak_mw_per_um2
               * T.leakage_scale(T.V_LOW))
    e_cycle_fj = max(p_target_mw - leak_mw, 1e-9) * 1e-3 / f_low * 1e15

    parts_scaled = dict(parts)
    parts_scaled["tree"] = parts["tree"].scaled(k_energy=ref.spec.w)
    parts_scaled["sa"] = parts["sa"].scaled(k_energy=ref.spec.w)
    e_rel = _mode_energy_rel(ref, parts_scaled, "int_lo", ACT_IN_MEAS, ACT_WT_MEAS)
    eps = e_cycle_fj / (e_rel * T.energy_scale(T.V_LOW))

    return base.with_calibration(tau_ps=tau, eps_fj=eps, apr_overhead=apr)


def at_voltage(design: MacroDesign, vdd: float) -> MacroDesign:
    """Re-target a design's reporting voltage (shmoo / Table II sweeps)."""
    return replace(design, spec=replace(design.spec, vdd=vdd))


def reference_chip_ppa(vdd: float | None = None) -> MacroPPA:
    tech = calibrated_tech_for_reference()
    design = reference_chip_design()
    if vdd is not None:
        design = at_voltage(design, vdd)
    return rollup(design, tech)
