"""SynDCIM core: the paper's contribution as an executable library.

Layers (paper Fig. 2):
  tech        40nm technology + voltage-scaling model (calibrated to silicon)
  subcircuits the seven DCIM subcircuit types and their PPA models
  csa         mixed compressor/FA carry-save adder-tree family (Fig. 4)
  scl         Subcircuit Library: characterized PPA lookup tables (Fig. 3)
  searcher    Multi-Spec-Oriented searcher — Algorithm 1
  pareto      Pareto-frontier utilities (Fig. 8), host/device/sharded masks
  engine      unified execution engine: plan -> place -> execute -> extract
  macro       spec -> design -> PPA roll-up (+ silicon calibration)
  netlist     RTL / structural netlist emission
  gatesim     functional gate-level simulation of synthesized trees
  dse         system-level workload -> macro-array mapping
"""

from .csa import CSADesign, CSAReport, FAMILY, build_netlist, characterize
from .dse import (AcceleratorReport, CodesignReport, GemmShape,
                  WorkloadMatrix, accelerator_report,
                  batched_workload_matrix, cross_workload_codesign,
                  gemm_inventory, map_gemm)
from .gatesim import simulate, verify_tree
from .macro import (MacroDesign, MacroPPA, MacroSpec, at_voltage,
                    calibrated_tech_for_reference, pareto_experiment_spec,
                    reference_chip_design, reference_chip_ppa,
                    reference_chip_spec, reporting_frequency, rollup,
                    timing_paths)
from .netlist import emit_verilog, tree_netlist
from .pareto import (PARETO_EPS, dominates, nondominated_mask,
                     nondominated_mask_auto, nondominated_mask_sharded,
                     pareto_front, pareto_chunk_size, pareto_indices,
                     preference_grid)
from .scl import SubcircuitLibrary
from .searcher import SearchResult, mso_search, synthesize_one
from .subcircuits import SC, MemCellKind, MultMuxKind, PPA
from .tech import TechModel, delay_scale, energy_scale

# The engine-layer modules are the only core modules that need jax;
# re-export their names lazily (PEP 562) so the scalar compiler layer stays
# import-light.
_BATCHED_EXPORTS = ("BatchedPPA", "BatchedSweep", "DesignLattice",
                    "SpecTables", "design_space_sweep", "mso_search_batched",
                    "pareto_mask")
_MULTISPEC_EXPORTS = ("design_space_sweep_many", "evaluate_many",
                      "frontier_union", "mso_search_many", "scenario_specs")
_SHARDSPEC_EXPORTS = ("design_space_sweep_many_sharded",
                      "evaluate_many_sharded", "mso_search_many_sharded",
                      "spec_variants")
_ENGINE_EXPORTS = ("ExecutionPlan", "PackedGroup", "Placement", "Strategy",
                   "execute", "extract_frontier", "register_strategy")


def __getattr__(name: str):
    if name in _BATCHED_EXPORTS:
        from . import batched
        return getattr(batched, name)
    if name in _MULTISPEC_EXPORTS:
        from . import multispec
        return getattr(multispec, name)
    if name in _SHARDSPEC_EXPORTS:
        from . import shardspec
        return getattr(shardspec, name)
    if name in _ENGINE_EXPORTS:
        from . import engine
        return getattr(engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "BatchedPPA", "BatchedSweep", "DesignLattice", "SpecTables",
    "design_space_sweep", "mso_search_batched", "pareto_mask",
    "design_space_sweep_many", "evaluate_many", "frontier_union",
    "mso_search_many", "pareto_chunk_size", "scenario_specs",
    "design_space_sweep_many_sharded", "evaluate_many_sharded",
    "mso_search_many_sharded", "spec_variants",
    "ExecutionPlan", "PackedGroup", "Placement", "Strategy", "execute",
    "extract_frontier", "register_strategy",
    "CSADesign", "CSAReport", "FAMILY", "build_netlist", "characterize",
    "AcceleratorReport", "CodesignReport", "GemmShape", "WorkloadMatrix",
    "accelerator_report", "batched_workload_matrix",
    "cross_workload_codesign", "gemm_inventory", "map_gemm",
    "reporting_frequency",
    "simulate", "verify_tree",
    "MacroDesign", "MacroPPA", "MacroSpec", "at_voltage",
    "calibrated_tech_for_reference", "pareto_experiment_spec",
    "reference_chip_design", "reference_chip_ppa", "reference_chip_spec",
    "rollup", "timing_paths",
    "emit_verilog", "tree_netlist",
    "PARETO_EPS", "dominates", "nondominated_mask", "nondominated_mask_auto",
    "nondominated_mask_sharded", "pareto_front", "pareto_indices",
    "preference_grid",
    "SubcircuitLibrary",
    "SearchResult", "mso_search", "synthesize_one",
    "SC", "MemCellKind", "MultMuxKind", "PPA",
    "TechModel", "delay_scale", "energy_scale",
]
