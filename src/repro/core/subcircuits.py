"""The seven DCIM subcircuit types and their PPA models (paper §II-B, Fig. 3).

Every subcircuit type offers several *variants* (circuit topologies from the
paper's survey) and a parametric PPA model.  The Subcircuit Library
(``repro.core.scl``) characterizes these models over a grid of dimensions and
timing constraints into lookup tables — mirroring the paper's
"custom cell characterization flow" + "parameterized RTL templates ...
estimated and scaled from synthesis data".

PPA conventions (see tech.py): delay in tau units (relative), energy in eps
units per cycle at 100% activity, area in um^2.  Voltage and activity scaling
are applied by the macro roll-up.
"""

from __future__ import annotations

import enum
import math
from dataclasses import dataclass, replace

from . import csa as csa_mod
from .tech import TechModel


class SC(enum.Enum):
    """Subcircuit types (paper §II-B)."""

    ALIGN = "fp_int_alignment"
    WLBL_DRIVER = "wl_bl_driver"
    MEMCELL = "memory_cell"
    MULTMUX = "multiplier_multiplexer"
    ADDER_TREE = "adder_tree"
    SHIFT_ADDER = "shift_adder"
    OFU = "output_fusion_unit"


@dataclass(frozen=True)
class PPA:
    delay_rel: float       # critical path through the subcircuit, tau units
    energy_rel: float      # per cycle at 100% activity, eps units
    area_um2: float
    latency_cycles: int = 0
    meta: tuple = ()

    def scaled(self, k_energy: float = 1.0, k_area: float = 1.0) -> "PPA":
        return PPA(self.delay_rel, self.energy_rel * k_energy,
                   self.area_um2 * k_area, self.latency_cycles, self.meta)


# ---------------------------------------------------------------------------
# Memory cells (paper §II-B "Memory Cell")
# ---------------------------------------------------------------------------


class MemCellKind(enum.Enum):
    SRAM_6T = "6T"          # foundry cell + read-select (TSMC ISSCC'24 style)
    DLATCH_8T = "8T"        # robust simultaneous read/write ([3])
    OAI_12T = "12T"         # OAI-gate based, design-feasibility oriented ([10])


def memcell_ppa(kind: MemCellKind, tech: TechModel) -> PPA:
    if kind is MemCellKind.SRAM_6T:
        return PPA(delay_rel=0.9, energy_rel=tech.e_sram_read_bit,
                   area_um2=tech.a_sram6t)
    if kind is MemCellKind.DLATCH_8T:
        return PPA(delay_rel=0.7, energy_rel=tech.e_sram_read_bit * 1.25,
                   area_um2=tech.a_sram8t)
    return PPA(delay_rel=0.8, energy_rel=tech.e_sram_read_bit * 1.45,
               area_um2=tech.a_sram12t)


MEMCELL_SUPPORTS_MACWRITE = {
    # simultaneous MAC + weight write (Table II "MAC-Write")
    MemCellKind.SRAM_6T: True,
    MemCellKind.DLATCH_8T: True,
    MemCellKind.OAI_12T: False,
}


# ---------------------------------------------------------------------------
# Bitwise multiplier + multiplexer (paper §II-B, three options)
# ---------------------------------------------------------------------------


class MultMuxKind(enum.Enum):
    PASS_1T = "1t_pass"       # area-efficient; voltage drop -> power/latency hit
    OAI22_FUSED = "oai22"     # fused mult+mux ([3]); scalable only to MCR<=2
    TG_NOR = "tg2t_nor"       # 2T transmission gate + NOR mult (common choice)


def multmux_ppa(kind: MultMuxKind, mcr: int, tech: TechModel) -> PPA:
    """Per-cell-site multiplier+mux PPA.  ``mcr`` memory rows share one
    compute row; the mux selects among them."""
    mux_levels = max(1, math.ceil(math.log2(max(2, mcr))))
    if kind is MultMuxKind.PASS_1T:
        d = tech.d_mult_pass1t + 0.6 * mux_levels
        e = tech.e_mult_pass1t + 0.3 * mux_levels
        a = tech.a_mult_pass1t * mcr + tech.a_mult_nor
    elif kind is MultMuxKind.OAI22_FUSED:
        if mcr > 2:
            raise ValueError("OAI22 fused mult+mux does not scale beyond MCR=2 "
                             "(paper §II-B)")
        d = tech.d_mult_oai22
        e = tech.e_mult_oai22
        a = tech.a_mult_oai22
    else:
        d = tech.d_mux2 * mux_levels + tech.d_mult_nor
        e = tech.e_mux2 * 0.4 * mux_levels + tech.e_mult_nor
        a = tech.a_tg2t * mcr + tech.a_mult_nor
    return PPA(delay_rel=d, energy_rel=e, area_um2=a)


def multmux_valid(kind: MultMuxKind, mcr: int) -> bool:
    return not (kind is MultMuxKind.OAI22_FUSED and mcr > 2)


# ---------------------------------------------------------------------------
# WL / BL drivers
# ---------------------------------------------------------------------------


def wl_driver_ppa(h_rows: int, w_cols: int, mcr: int, tech: TechModel) -> PPA:
    """Word-line drivers: one per (physical) row; drive W columns of wire+gates.
    Energy reported per cycle assuming every row toggles (activity applied
    upstream)."""
    n_rows = h_rows * mcr
    d = tech.d_wl_driver_base + tech.d_wl_driver_per_log2col * math.log2(max(2, w_cols))
    e = n_rows * w_cols * tech.e_wl_per_cell
    a = n_rows * tech.a_driver_per_row
    return PPA(delay_rel=d, energy_rel=e, area_um2=a)


def bl_driver_ppa(h_rows: int, w_cols: int, mcr: int, tech: TechModel) -> PPA:
    """Bit-line write drivers: one per column pair; active only on weight
    updates (duty factor applied by the macro roll-up)."""
    d = tech.d_wl_driver_base + tech.d_wl_driver_per_log2col * math.log2(max(2, h_rows * mcr))
    e = h_rows * mcr * w_cols * tech.e_bl_per_cell  # full-array write energy
    a = w_cols * tech.a_driver_per_col
    return PPA(delay_rel=d, energy_rel=e, area_um2=a)


# ---------------------------------------------------------------------------
# Shift & Adder (bit-serial accumulator, paper §II-B "S&A")
# ---------------------------------------------------------------------------


def shift_adder_ppa(acc_width: int, input_bits: int, tech: TechModel) -> PPA:
    """Accumulates bit-serial partial sums: width grows with input bit-width
    and tree accumulator width."""
    w = acc_width + input_bits
    d = tech.d_rca_per_bit * w + tech.d_reg_cq_su
    e = w * (tech.e_fa * 0.8 + tech.e_reg * 0.3 + tech.e_clk_per_reg)
    a = w * (tech.a_fa + tech.a_reg)
    return PPA(delay_rel=d, energy_rel=e, area_um2=a, latency_cycles=1)


# ---------------------------------------------------------------------------
# Output Fusion Unit (multi-precision reconfigurability, paper §II-B "OFU")
# ---------------------------------------------------------------------------


def ofu_ppa(w_cols: int, weight_precisions: tuple[int, ...], out_width: int,
            pipe_stages: int, tech: TechModel) -> PPA:
    """Fuses S&A outputs across columns stage by stage, low to high precision
    ([9]).  ``weight_precisions`` e.g. (1,2,4,8): fusion stages = log2(max/min).
    ``pipe_stages`` extra pipeline registers (tt5) split the fusion chain.
    """
    pmax, pmin = max(weight_precisions), min(weight_precisions)
    stages = max(1, int(math.log2(pmax // pmin))) if pmax > pmin else 1
    groups = w_cols // 2  # adders at the widest fusion stage
    w = out_width + int(math.log2(max(2, pmax)))
    d_stage = tech.d_rca_per_bit * w + tech.d_mux2
    cuts = max(0, min(pipe_stages, stages - 1))
    d = d_stage * math.ceil(stages / (cuts + 1)) + tech.d_reg_cq_su
    lat = 1 + cuts
    n_adders = sum(max(1, groups >> s) for s in range(stages))
    e = n_adders * w * (tech.e_fa * 0.7) + (w * lat) * tech.e_clk_per_reg
    a = n_adders * w * tech.a_fa * 0.6 + w * lat * tech.a_reg
    return PPA(delay_rel=d, energy_rel=e, area_um2=a, latency_cycles=lat)


# ---------------------------------------------------------------------------
# FP & INT Alignment Unit (paper §II-B)
# ---------------------------------------------------------------------------

FP_FORMATS = {
    # name: (exp_bits, man_bits)
    "FP4": (2, 1),
    "FP8": (4, 3),      # E4M3
    "BF16": (8, 7),
}


def align_ppa(w_cols: int, fp_formats: tuple[str, ...], tech: TechModel) -> PPA:
    """Comparator tree (max exponent across the column group) + mantissa
    shifters ([9]).  Complexity depends on the *combination* of FP precisions
    supported."""
    if not fp_formats:
        return PPA(0.0, 0.0, 0.0)
    emax = max(FP_FORMATS[f][0] for f in fp_formats)
    mmax = max(FP_FORMATS[f][1] for f in fp_formats)
    cmp_levels = math.ceil(math.log2(max(2, w_cols)))
    d = tech.d_cmp_per_bit * emax * cmp_levels + tech.d_mux2 * math.ceil(math.log2(mmax + 2))
    # One comparator per pair per level + a barrel shifter per column.
    n_cmp = w_cols - 1
    shift_stages = math.ceil(math.log2(mmax + 2))
    e = (n_cmp * emax * tech.e_xor * 1.2
         + w_cols * (mmax + 1) * shift_stages * tech.e_mux2)
    a = (n_cmp * emax * tech.a_xor * 1.5
         + w_cols * (mmax + 1) * shift_stages * tech.a_mux2)
    # Extra formats beyond the first add mode-mux overhead:
    k = 1.0 + 0.18 * (len(fp_formats) - 1)
    return PPA(delay_rel=d, energy_rel=e * k, area_um2=a * k, latency_cycles=1)


# ---------------------------------------------------------------------------
# Adder tree (delegates to csa.py) + approximate compressor cells
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ApproxCellSpec:
    """An approximate adder-tree cell variant (OpenACM-style): the exact
    4:2 compressor / full-adder cells are swapped for approximate ones whose
    error is absorbed by the workload.  PPA is modeled as first-order scale
    factors on the characterized exact tree — the tree *structure* (stage
    count, register placement, accumulator widths, latency) is unchanged, so
    an approximate variant slots into the same lattice point shape."""

    name: str = "exact"
    k_delay: float = 1.0
    k_energy: float = 1.0
    k_area: float = 1.0

    def __post_init__(self):
        if min(self.k_delay, self.k_energy, self.k_area) <= 0.0:
            raise ValueError("approximate-cell scale factors must be > 0")

    def is_exact(self) -> bool:
        return self.k_delay == self.k_energy == self.k_area == 1.0


#: The exact (seed) cell — scale factors of 1.0 reproduce the characterized
#: tree bit-for-bit.
EXACT_CELL = ApproxCellSpec()

#: A small catalog of approximate compressor variants (first-order numbers in
#: the spirit of the OpenACM lower-part-OR / truncation families).
APPROX_CELLS: tuple[ApproxCellSpec, ...] = (
    EXACT_CELL,
    ApproxCellSpec(name="loa4", k_delay=0.92, k_energy=0.71, k_area=0.78),
    ApproxCellSpec(name="trunc8", k_delay=0.85, k_energy=0.55, k_area=0.64),
)


def approx_tree_report(rep: csa_mod.CSAReport,
                       cell: ApproxCellSpec | None) -> csa_mod.CSAReport:
    """Apply an approximate cell's scale factors to a characterized exact
    tree.  ``None`` or the exact cell returns the report unchanged (the same
    object — bit-identity with the pre-approximation path)."""
    if cell is None or cell.is_exact():
        return rep
    return replace(rep,
                   crit_path_rel=rep.crit_path_rel * cell.k_delay,
                   energy_rel=rep.energy_rel * cell.k_energy,
                   area_um2=rep.area_um2 * cell.k_area)


def adder_tree_ppa(design: csa_mod.CSADesign, h_rows: int, product_bits: int,
                   tech: TechModel,
                   cell: ApproxCellSpec | None = None
                   ) -> tuple[PPA, csa_mod.CSAReport]:
    rep = approx_tree_report(
        csa_mod.characterize(design, h_rows, product_bits, tech), cell)
    meta = (design.name(),) if cell is None or cell.is_exact() \
        else (design.name(), cell.name)
    ppa = PPA(delay_rel=rep.crit_path_rel, energy_rel=rep.energy_rel,
              area_um2=rep.area_um2, latency_cycles=rep.latency_cycles,
              meta=meta)
    return ppa, rep
