from .quantizers import (QuantSpec, block_fp_align, dequantize, fake_quant,
                         fp8_e4m3_quant, quantize_int)

__all__ = ["QuantSpec", "block_fp_align", "dequantize", "fake_quant",
           "fp8_e4m3_quant", "quantize_int"]
