"""Quantization substrate for DCIM execution semantics.

The synthesized macros execute INT1/2/4/8 natively and FP4/FP8/BF16 through
the FP&INT alignment unit (comparator tree finds the block-max exponent, then
mantissas shift into integer alignment — [9], paper §II-B).  This module makes
those semantics executable in JAX:

  * ``quantize_int`` / ``dequantize``   — symmetric per-axis INT quantization
  * ``block_fp_align``                  — the alignment unit: block floating
    point (shared exponent + shifted integer mantissas), exactly the
    transform the hardware applies before the adder tree
  * ``fake_quant``                      — straight-through-estimator QAT node
    used by DCIM linear layers during training
  * ``fp8_e4m3_quant``                  — FP8 value grid (saturating)
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class QuantSpec:
    """Precision configuration of a DCIM-mapped layer (macro modes)."""

    a_bits: int = 8          # activation (bit-serial input) precision
    w_bits: int = 8          # weight (stored) precision
    mode: str = "int"        # 'int' | 'fp8' | 'bf16' (alignment-unit modes)

    def __post_init__(self):
        assert self.a_bits in (1, 2, 4, 8, 16)
        assert self.w_bits in (1, 2, 4, 8, 16)
        assert self.mode in ("int", "fp8", "bf16")


def _qmax(bits: int) -> int:
    return (1 << (bits - 1)) - 1


def quantize_int(x: jnp.ndarray, bits: int, axis: int | None = -1,
                 eps: float = 1e-8) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric linear quantization to signed ``bits`` integers.

    Returns (q int8, scale f32) with x ≈ q * scale.  ``axis=None`` gives a
    per-tensor scale; otherwise the scale is per-slice along ``axis``
    (per-channel for weights, per-row for activations).
    """
    qmax = _qmax(bits)
    if axis is None:
        amax = jnp.max(jnp.abs(x))
    else:
        amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, eps) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def fake_quant(x: jnp.ndarray, bits: int, axis: int | None = -1) -> jnp.ndarray:
    """Quantize-dequantize with a straight-through gradient (QAT)."""
    q, s = quantize_int(x, bits, axis)
    return (q.astype(x.dtype) * s.astype(x.dtype)).astype(x.dtype)


def _fq_fwd(x, bits, axis):
    return fake_quant(x, bits, axis), None


def _fq_bwd(bits, axis, _res, g):
    return (g,)   # straight-through


fake_quant.defvjp(_fq_fwd, _fq_bwd)


def block_fp_align(x: jnp.ndarray, man_bits: int, block_axis: int = -1
                   ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """The FP&INT alignment unit, executable.

    Per block (a slice along ``block_axis``): find the max exponent
    (comparator tree), shift every mantissa right so all values share that
    exponent (shifters), emit integer mantissas.  Returns
    (mantissas int32, shared_scale f32) with x ≈ mantissas * shared_scale.
    """
    absx = jnp.abs(x)
    bmax = jnp.max(absx, axis=block_axis, keepdims=True)
    # shared exponent: smallest e with max(|x|) < 2^e
    e = jnp.ceil(jnp.log2(jnp.maximum(bmax, 1e-30)))
    shared_scale = jnp.exp2(e - man_bits)          # LSB weight after shift
    man = jnp.clip(jnp.round(x / shared_scale),
                   -(1 << man_bits), (1 << man_bits) - 1)
    return man.astype(jnp.int32), shared_scale.astype(jnp.float32)


def fp8_e4m3_quant(x: jnp.ndarray) -> jnp.ndarray:
    """Round to the FP8 E4M3 grid (saturating at +-448) and back to f32."""
    y = x.astype(jnp.float32)
    y = jnp.clip(y, -448.0, 448.0)
    return y.astype(jnp.float8_e4m3fn).astype(jnp.float32)
