from .pipeline import DataConfig, SyntheticCorpus, host_sharded_loader

__all__ = ["DataConfig", "SyntheticCorpus", "host_sharded_loader"]
