"""Deterministic synthetic data pipeline with host sharding + prefetch.

At 1000+ nodes the data layer must be (a) deterministic under restart — a
step index fully determines the batch, so resuming from a checkpoint replays
no examples and skips none — and (b) host-sharded — each host materializes
only its slice of the global batch.  Both properties hold here:

  * tokens are a counter-based hash (splitmix64) of (seed, step, position) —
    no state, O(1) seek to any step;
  * ``host_sharded_loader`` slices the global batch by (host_id, n_hosts) and
    prefetches on a background thread.

The synthetic stream is Zipf-shaped over the vocab so losses/router balance
behave like text rather than uniform noise.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_alpha: float = 1.1
    frontend_tokens: int = 0
    frontend_dim: int = 0


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
    z = x
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


class SyntheticCorpus:
    """Counter-based deterministic corpus: batch(step) is a pure function."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # Zipf CDF over the vocab for text-like marginal statistics.
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_alpha)
        self._cdf = np.cumsum(p / p.sum())

    def batch(self, step: int, lo: int = 0, hi: int | None = None) -> dict:
        """Rows [lo, hi) of the global batch at ``step``."""
        c = self.cfg
        hi = c.global_batch if hi is None else hi
        rows = np.arange(lo, hi, dtype=np.uint64)
        pos = np.arange(c.seq_len + 1, dtype=np.uint64)
        ctr = (np.uint64(c.seed) << np.uint64(40)) \
            + (np.uint64(step) << np.uint64(20))
        h = _splitmix64(ctr + (rows[:, None] << np.uint64(32)) + pos[None, :])
        u = (h >> np.uint64(11)).astype(np.float64) / float(1 << 53)
        toks = np.searchsorted(self._cdf, u).astype(np.int32)
        toks = np.clip(toks, 0, c.vocab - 1)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if c.frontend_tokens:
            fh = _splitmix64(ctr + np.uint64(0xF00D) +
                             (rows[:, None] << np.uint64(32)) +
                             np.arange(c.frontend_tokens * c.frontend_dim,
                                       dtype=np.uint64)[None, :])
            fe = ((fh >> np.uint64(11)).astype(np.float64) / float(1 << 53))
            fe = (fe.reshape(len(rows), c.frontend_tokens, c.frontend_dim)
                  .astype(np.float32) * 2 - 1)
            out["frontend"] = fe
        return out


def host_sharded_loader(corpus: SyntheticCorpus, host_id: int, n_hosts: int,
                        start_step: int = 0, prefetch: int = 2):
    """Generator of this host's batch slices with background prefetch."""
    c = corpus.cfg
    per_host = c.global_batch // n_hosts
    lo = host_id * per_host
    hi = lo + per_host
    q: queue.Queue = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def worker():
        step = start_step
        while not stop.is_set():
            q.put((step, corpus.batch(step, lo, hi)))
            step += 1

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()
