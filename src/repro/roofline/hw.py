"""TPU v5e hardware constants for the roofline model (per task spec)."""

PEAK_BF16_FLOPS = 197e12        # FLOP/s per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_LINK_BW = 50e9              # bytes/s per link
ICI_LINKS_PER_CHIP = 4          # 2D torus: 4 links usable per chip (v5e)
HBM_PER_CHIP = 16 * 2**30       # 16 GiB

# Inter-pod (DCN) for the multi-pod mesh's 'pod' axis:
DCN_BW_PER_CHIP = 6.25e9        # bytes/s per chip (50 Gbit/s NIC share)


def compute_time_s(flops: float, chips: int) -> float:
    return flops / (chips * PEAK_BF16_FLOPS)


def memory_time_s(bytes_: float, chips: int) -> float:
    return bytes_ / (chips * HBM_BW)


def collective_time_s(coll_bytes_per_chip: float) -> float:
    """coll_bytes_per_chip: ICI traffic already normalized per chip."""
    return coll_bytes_per_chip / (ICI_LINKS_PER_CHIP * ICI_LINK_BW)
