"""EXPERIMENTS.md §Dry-run + §Roofline table generation from dry-run
artifacts.  Regenerate after any sweep/hillclimb with:

    PYTHONPATH=src python -m repro.roofline.report artifacts/dryrun
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

from ..configs import SHAPES, applicable_shapes, get_config, list_archs
from . import hw


def model_flops_per_step(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch


def load_records(artdir: Path) -> dict[tuple, dict]:
    out = {}
    for p in sorted(artdir.glob("*.json")):
        rec = json.loads(p.read_text())
        out[(rec.get("arch"), rec.get("shape"), rec.get("mesh"))] = rec
    return out


def fmt_bytes(b: float) -> str:
    if b >= 1e9:
        return f"{b / 1e9:.2f}G"
    if b >= 1e6:
        return f"{b / 1e6:.1f}M"
    return f"{b / 1e3:.0f}K"


def roofline_terms(rec: dict) -> dict:
    c = rec["cost"]
    t_c = c["flops_per_device"] / hw.PEAK_BF16_FLOPS
    t_m = c["bytes_per_device"] / hw.HBM_BW
    t_x = hw.collective_time_s(c["coll_bytes_per_device"])
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    mf = model_flops_per_step(rec["arch"], rec["shape"])
    chips = rec["devices"]
    useful = mf / (c["flops_per_device"] * chips) if c["flops_per_device"] else 0
    bound = max(t_c, t_m, t_x)
    mfu = (mf / chips / hw.PEAK_BF16_FLOPS) / bound if bound else 0.0
    return {"t_c": t_c, "t_m": t_m, "t_x": t_x, "dom": dom, "mf": mf,
            "useful": useful, "mfu_bound": mfu, "bound_s": bound}


def dryrun_table(records: dict) -> str:
    lines = ["| arch | shape | mesh | compile_s | HBM/chip (analysis) | "
             "HLO GFLOP/chip | HBM GB/chip | coll MB/chip | top collectives |",
             "|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape, mesh), rec in sorted(records.items()):
        if not rec.get("ok"):
            lines.append(f"| {arch} | {shape} | {mesh} | FAILED | | | | | "
                         f"{rec.get('error', '')[:60]} |")
            continue
        c = rec["cost"]
        mem = rec.get("memory", {})
        cc = sorted(c["coll_counts"].items(),
                    key=lambda kv: -kv[1]["bytes"])[:2]
        ccs = "; ".join(f"{k}x{int(v['count'])}={fmt_bytes(v['bytes'])}"
                        for k, v in cc)
        lines.append(
            f"| {arch} | {shape} | {mesh} | {rec.get('compile_s', '?')} | "
            f"{fmt_bytes(mem.get('total_bytes_per_device', 0))} | "
            f"{c['flops_per_device'] / 1e9:.1f} | "
            f"{c['bytes_per_device'] / 1e9:.2f} | "
            f"{c['coll_bytes_per_device'] / 1e6:.1f} | {ccs} |")
    return "\n".join(lines)


def roofline_table(records: dict, mesh: str = "single") -> str:
    lines = ["| arch | shape | t_compute | t_memory | t_collective | "
             "bottleneck | MODEL_FLOPS | useful/HLO | roofline-MFU bound |",
             "|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape, m), rec in sorted(records.items()):
        if m != mesh or not rec.get("ok"):
            continue
        r = roofline_terms(rec)
        lines.append(
            f"| {arch} | {shape} | {r['t_c'] * 1e3:.2f} ms | "
            f"{r['t_m'] * 1e3:.2f} ms | {r['t_x'] * 1e3:.2f} ms | "
            f"**{r['dom']}** | {r['mf']:.2e} | {r['useful']:.3f} | "
            f"{r['mfu_bound']:.3f} |")
    return "\n".join(lines)


def skip_table() -> str:
    lines = ["| arch | skipped shape | reason |", "|---|---|---|"]
    for arch in list_archs():
        cfg = get_config(arch)
        have = set(applicable_shapes(cfg))
        for s in SHAPES:
            if s not in have:
                lines.append(f"| {arch} | {s} | full-attention arch: 500k "
                             f"dense-KV decode is quadratic-history; spec "
                             f"says skip (DESIGN.md §5) |")
    return "\n".join(lines)


def main():
    artdir = Path(sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun")
    records = load_records(artdir)
    n_ok = sum(1 for r in records.values() if r.get("ok"))
    print(f"## Dry-run matrix ({n_ok}/{len(records)} cells compiled)\n")
    print(dryrun_table(records))
    print("\n### Skipped cells\n")
    print(skip_table())
    print("\n## Roofline (single-pod 16x16 = 256 chips)\n")
    print(roofline_table(records, "single"))
    print("\n## Roofline (multi-pod 2x16x16 = 512 chips)\n")
    print(roofline_table(records, "multi"))


if __name__ == "__main__":
    main()
