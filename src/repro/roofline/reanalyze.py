"""Recompute the cost block of dry-run artifacts from their stored HLO
(no recompilation):  PYTHONPATH=src python -m repro.roofline.reanalyze <dir>
"""

import gzip
import json
import sys
from pathlib import Path

from .hlo_parse import analyze_hlo_text


def main():
    d = Path(sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun")
    for j in sorted(d.glob("*.json")):
        rec = json.loads(j.read_text())
        hlo = j.with_suffix("").with_suffix("")  # strip .json
        hz = d / (j.stem + ".hlo.gz")
        if not rec.get("ok") or not hz.exists():
            continue
        txt = gzip.open(hz, "rt").read()
        rec["cost_raw"] = rec.get("cost_raw", rec.get("cost"))
        rec["cost"] = analyze_hlo_text(txt, rec["devices"], bf16_normalize=True)
        j.write_text(json.dumps(rec, indent=1))
        print("reanalyzed", j.stem)


if __name__ == "__main__":
    main()
