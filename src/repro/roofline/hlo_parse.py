"""HLO-text cost walker: trip-count-aware FLOPs / bytes / collective-bytes.

``compiled.cost_analysis()`` counts a ``while`` body ONCE regardless of trip
count (verified on this jax build: an 8-step scan reports 1/8 the FLOPs of the
unrolled version).  Since every model here scans over layers, that undercount
would poison the roofline, so this module re-derives costs from
``compiled.as_text()``:

  * parses every computation and instruction (result shape, opcode, operands,
    attributes),
  * extracts while trip counts from the condition computation's s32 constant
    (scan induction: ``i < L``),
  * walks the call graph multiplying by trip counts:
      - dot: 2 x |result| x contracted-dim product (from the lhs operand shape)
      - elementwise/reduce: |result| FLOPs (minor terms)
      - fusion: recurse for FLOPs; bytes only at the fusion boundary
        (operands + results — the HBM-traffic proxy)
      - collectives: per-chip ICI bytes with ring-algorithm multipliers
        (all-reduce 2(g-1)/g, all-gather/reduce-scatter/all-to-all (g-1)/g,
        collective-permute 1x), group size from replica_groups.

Shapes in post-SPMD HLO are PER-DEVICE, so collective bytes are already
per-chip quantities.
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field

DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


@dataclass
class Shape:
    dtype: str
    dims: tuple[int, ...]

    @property
    def elems(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n

    @property
    def bytes(self) -> float:
        return self.elems * DTYPE_BYTES.get(self.dtype, 4)


def parse_shapes(type_str: str) -> list[Shape]:
    """'f32[64,256]' or '(s32[], f32[64,64])' -> list of Shapes."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dims = tuple(int(x) for x in m.group(2).split(",") if x)
        out.append(Shape(m.group(1), dims))
    return out


@dataclass
class Instr:
    name: str
    shapes: list[Shape]
    opcode: str
    operands: list[str]
    attrs: str
    args: str = ""

    @property
    def result_bytes(self) -> float:
        return sum(s.bytes for s in self.shapes)

    @property
    def result_elems(self) -> int:
        return sum(s.elems for s in self.shapes)


@dataclass
class Computation:
    name: str
    instrs: list[Instr] = field(default_factory=list)
    shape_table: dict[str, list[Shape]] = field(default_factory=dict)
    instr_by_name: dict[str, Instr] = field(default_factory=dict)


_COMP_HEADER = re.compile(
    r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*.*\{\s*$")
_TRIP_COUNT_BC = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?))\s*"
    r"([\w\-]+)\((.*?)\)(.*)$")
_OPERAND = re.compile(r"%([\w.\-]+)")
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_NEW = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_OLD = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CONST_S32 = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_LHS_CDIMS = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HEADER.match(line.strip())
            if m:
                cur = Computation(m.group(1))
                # parameter shapes from the header
                if m.group(2):
                    for pm in re.finditer(r"([\w.\-]+):\s*((?:\([^)]*\)|[a-z0-9]+\[[\d,]*\]))",
                                          m.group(2)):
                        cur.shape_table[pm.group(1)] = parse_shapes(pm.group(2))
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            name, type_str, opcode, args, attrs = m.groups()
            shapes = parse_shapes(type_str)
            operands = _OPERAND.findall(args)
            ins = Instr(name, shapes, opcode, operands, attrs, args)
            cur.instrs.append(ins)
            cur.shape_table[name] = shapes
            cur.instr_by_name[name] = ins
    return comps


# ---------------------------------------------------------------------------
# cost walking
# ---------------------------------------------------------------------------

FLOPS_FREE = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
              "copy", "reshape", "transpose", "broadcast", "iota", "slice",
              "dynamic-slice", "dynamic-update-slice", "concatenate", "pad",
              "gather", "scatter", "convert", "reverse", "custom-call",
              "partition-id", "replica-id", "after-all", "rng-bit-generator",
              "select-and-scatter", "while", "conditional", "call", "fusion"}

BYTES_FREE = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
              "partition-id", "replica-id", "after-all", "iota",
              "copy"}  # loop-carried copies alias on real hardware


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0      # per-chip ICI traffic
    coll_counts: dict = field(default_factory=dict)
    bytes_by_op: dict = field(default_factory=dict)
    flops_by_op: dict = field(default_factory=dict)

    def add(self, other: "Cost", k: float = 1.0):
        self.flops += other.flops * k
        self.bytes += other.bytes * k
        self.coll_bytes += other.coll_bytes * k
        for op, (cnt, by) in other.coll_counts.items():
            c0, b0 = self.coll_counts.get(op, (0.0, 0.0))
            self.coll_counts[op] = (c0 + cnt * k, b0 + by * k)
        for d_self, d_other in ((self.bytes_by_op, other.bytes_by_op),
                                (self.flops_by_op, other.flops_by_op)):
            for op, v in d_other.items():
                d_self[op] = d_self.get(op, 0.0) + v * k


def _group_size(attrs: str, default: int) -> int:
    m = _GROUPS_NEW.search(attrs)
    if m:
        return max(1, int(m.group(2)))
    m = _GROUPS_OLD.search(attrs)
    if m:
        return max(1, len(m.group(1).split(",")))
    return default


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_elems = ins.result_elems
    cdims = []
    m = _LHS_CDIMS.search(ins.attrs)
    if m and m.group(1):
        cdims = [int(x) for x in m.group(1).split(",")]
    csize = 1
    if ins.operands:
        lhs_shapes = comp.shape_table.get(ins.operands[0])
        if lhs_shapes:
            lhs = lhs_shapes[0]
            for c in cdims:
                if c < len(lhs.dims):
                    csize *= lhs.dims[c]
    return 2.0 * out_elems * max(1, csize)


def _conv_flops(ins: Instr, comp: Computation) -> float:
    # rough: 2 * |out| * (kernel elems / out-channels)
    if len(ins.operands) >= 2:
        ksh = comp.shape_table.get(ins.operands[1])
        if ksh:
            k = ksh[0]
            return 2.0 * ins.result_elems * max(1, k.elems // max(1, k.dims[-1]))
    return 2.0 * ins.result_elems


def _trip_count(cond: Computation) -> int:
    """Largest s32 scalar constant in the condition region (scan: i < L)."""
    best = 1
    for ins in cond.instrs:
        if ins.opcode == "constant" and ins.shapes and \
                ins.shapes[0].dtype == "s32" and not ins.shapes[0].dims:
            m = re.match(r"\s*(\d+)\s*$", ins.args)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _instr_coll_bytes(ins: Instr, comp: Computation, n_devices: int) -> float:
    g = _group_size(ins.attrs, n_devices)
    if g <= 1:
        return 0.0
    frac = (g - 1) / g
    if ins.opcode == "all-reduce":
        return 2.0 * ins.result_bytes * frac
    if ins.opcode == "all-gather":
        return ins.result_bytes * frac          # result is the gathered tensor
    if ins.opcode == "reduce-scatter":
        return ins.result_bytes * (g - 1)       # operand = g x result
    if ins.opcode == "all-to-all":
        return ins.result_bytes * frac
    if ins.opcode == "collective-permute":
        return ins.result_bytes
    return 0.0


class CostWalker:
    def __init__(self, comps: dict[str, Computation], n_devices: int,
                 bf16_normalize: bool = False):
        self.comps = comps
        self.n_devices = n_devices
        self.bf16_normalize = bf16_normalize
        self._memo: dict[tuple[str, bool], Cost] = {}
        self._dus_memo: dict[str, bool] = {}
        self.trip_counts: dict[str, int] = {}

    def _comp_has_dus(self, name: str) -> bool:
        if name in self._dus_memo:
            return self._dus_memo[name]
        self._dus_memo[name] = False   # cycle guard
        comp = self.comps.get(name)
        found = False
        if comp is not None:
            for ins in comp.instrs:
                if ins.opcode == "dynamic-update-slice":
                    found = True
                    break
                if ins.opcode == "fusion":
                    m = _CALLS.search(ins.attrs)
                    if m and self._comp_has_dus(m.group(1)):
                        found = True
                        break
        self._dus_memo[name] = found
        return found

    def _is_inplace_update(self, ins: Instr) -> bool:
        if ins.opcode == "dynamic-update-slice":
            return True
        if ins.opcode == "fusion":
            m = _CALLS.search(ins.attrs)
            if m:
                return self._comp_has_dus(m.group(1))
        return False

    HEAVY = {"dot", "convolution", "reduce", "reduce-window", "sort",
             "rng-bit-generator"}

    def _comp_has_heavy(self, name: str) -> bool:
        key = "H:" + name
        if key in self._dus_memo:
            return self._dus_memo[key]
        self._dus_memo[key] = False
        comp = self.comps.get(name)
        found = False
        if comp is not None:
            for ins in comp.instrs:
                if ins.opcode in self.HEAVY or \
                        ins.opcode in ("gather", "scatter",
                                       "dynamic-update-slice"):
                    found = True
                    break
                if ins.opcode == "fusion":
                    m = _CALLS.search(ins.attrs)
                    if m and self._comp_has_heavy(m.group(1)):
                        found = True
                        break
        self._dus_memo[key] = found
        return found

    def _operand_bytes(self, ins: Instr, comp: Computation,
                       through_convert: bool = False) -> list[float]:
        """Operand byte sizes; with ``through_convert`` an operand produced by
        a dtype convert is counted at its SOURCE size — XLA:CPU upcasts bf16
        dots to f32 (convert -> f32 dot), which a TPU would read natively in
        bf16, so the roofline must charge the pre-convert bytes."""
        out = []
        for o in ins.operands:
            sh = comp.shape_table.get(o)
            if not sh:
                continue
            if through_convert and self.bf16_normalize:
                src = comp.instr_by_name.get(o)
                if src is not None and src.opcode == "convert" and src.operands:
                    ssh = comp.shape_table.get(src.operands[0])
                    if ssh:
                        out.append(sum(s.bytes for s in ssh))
                        continue
                if src is not None and src.opcode == "fusion" and \
                        "convert" in src.name and src.operands:
                    # convert-only fusions keep the converted tensor name
                    ssh = comp.shape_table.get(src.operands[0])
                    if ssh and abs(sum(s.bytes for s in ssh) * 2
                                   - sum(s.bytes for s in sh)) < 1:
                        out.append(sum(s.bytes for s in ssh))
                        continue
            out.append(sum(s.bytes for s in sh))
        return out

    def _norm_f32(self, bytes_: float, shapes: list[Shape]) -> float:
        """Halve f32 tensor bytes under bf16 normalization (TPU projection:
        partial sums / collectives of bf16 dots stay bf16 on TPU)."""
        if not self.bf16_normalize:
            return bytes_
        if shapes and all(s.dtype == "f32" for s in shapes if s.elems > 1):
            return bytes_ * 0.5
        return bytes_

    def _heavy_bytes(self, ins: Instr, comp: Computation) -> float:
        op = ins.opcode
        if op in BYTES_FREE:
            return 0.0
        if op in ("dot", "convolution"):
            opbs = self._operand_bytes(ins, comp, through_convert=True)
            return sum(opbs) + self._norm_f32(ins.result_bytes, ins.shapes)
        opbs = self._operand_bytes(ins, comp)
        if op in ("reduce", "reduce-window", "sort", "rng-bit-generator"):
            return sum(opbs) + ins.result_bytes
        if op in COLLECTIVES:
            return self._norm_f32(sum(opbs) + ins.result_bytes, ins.shapes)
        if self._is_inplace_update(ins):
            # In-place (cache) updates: on TPU the destination aliases the
            # result (donated buffers), nested scan-carried DUS chains alias
            # transitively, and the update payload was already charged at its
            # producer (the K/V projection dot results).  Charging the
            # boundary here would double-count the whole cache per layer per
            # step (verified on the llama decode HLO), so in-place updates
            # contribute no independent HBM traffic.  Cache *reads* are fully
            # charged at the attention dots' operands.
            return 0.0
        if op in ("gather", "dynamic-slice"):
            return 2.0 * ins.result_bytes          # read slice + write
        if op == "scatter":
            return sum(opbs) - (max(opbs) if opbs else 0.0) + ins.result_elems * 0
        if op == "fusion":
            m = _CALLS.search(ins.attrs)
            if m and self._comp_has_heavy(m.group(1)):
                return self._norm_f32(sum(opbs) + ins.result_bytes, ins.shapes)
            return 0.0                             # elementwise fusion: fused
        return 0.0                                 # raw elementwise: fused

    def total(self, entry: str | None = None) -> Cost:
        if entry is None:
            entry = next((n for n in self.comps if "main" in n),
                         next(iter(self.comps)))
        return self.comp_cost(entry, fused=False)

    def comp_cost(self, name: str, fused: bool) -> Cost:
        key = (name, fused)
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(name)
        cost = Cost()
        self._memo[key] = cost  # break cycles defensively
        if comp is None:
            return cost
        for ins in comp.instrs:
            self._instr_cost(ins, comp, cost, fused)
        return cost

    def _instr_cost(self, ins: Instr, comp: Computation, cost: Cost,
                    fused: bool):
        op = ins.opcode
        # FLOPs
        fl = 0.0
        if op == "dot":
            fl = _dot_flops(ins, comp)
        elif op == "convolution":
            fl = _conv_flops(ins, comp)
        elif op in ("reduce", "reduce-window"):
            fl = ins.result_elems * 2
        elif op == "sort":
            n = ins.result_elems
            fl = n * max(1, math.log2(max(2, n)))
        elif op not in FLOPS_FREE and op not in COLLECTIVES:
            fl = ins.result_elems               # elementwise & friends
        if fl:
            cost.flops += fl
            key = op if op in ("dot", "convolution", "reduce", "sort") else "elementwise"
            cost.flops_by_op[key] = cost.flops_by_op.get(key, 0.0) + fl

        # bytes: HBM-traffic model assuming TPU-grade fusion — only "heavy"
        # ops inherently touch HBM (matmuls/conv read operands + write
        # results; gathers/reduces/collectives likewise; cache updates write
        # the update).  Pure elementwise chains are assumed fused into their
        # heavy neighbors (XLA:TPU behavior), so they contribute FLOPs but no
        # independent traffic.  The CPU-backend HLO fuses far less, which is
        # why boundary-counting overestimates ~50x (see DESIGN.md §8 notes).
        if not fused:
            by = self._heavy_bytes(ins, comp)
            if by:
                cost.bytes += by
                cost.bytes_by_op[op] = cost.bytes_by_op.get(op, 0.0) + by

        # collectives
        if op in COLLECTIVES:
            cb = self._norm_f32(
                _instr_coll_bytes(ins, comp, self.n_devices), ins.shapes)
            cost.coll_bytes += cb
            c0, b0 = cost.coll_counts.get(op, (0.0, 0.0))
            cost.coll_counts[op] = (c0 + 1, b0 + cb)

        # control flow / calls
        if op == "while":
            bm = _BODY.search(ins.attrs)
            cm = _COND.search(ins.attrs)
            tc = _TRIP_COUNT_BC.search(ins.attrs)
            if tc:
                trips = int(tc.group(1))        # XLA's own known_trip_count
            elif cm and cm.group(1) in self.comps:
                trips = _trip_count(self.comps[cm.group(1)])
            else:
                trips = 1
            self.trip_counts[ins.name] = trips
            if bm:
                cost.add(self.comp_cost(bm.group(1), fused=False), trips)
            if cm:
                cost.add(self.comp_cost(cm.group(1), fused=False), trips)
        elif op == "conditional":
            bm = _BRANCHES.search(ins.attrs)
            if bm:
                branches = _OPERAND.findall(bm.group(1)) or \
                    [b.strip().lstrip("%") for b in bm.group(1).split(",")]
                sub = [self.comp_cost(b, fused=False) for b in branches
                       if b in self.comps]
                if sub:
                    worst = max(sub, key=lambda c: c.flops)
                    cost.add(worst)
        elif op in ("fusion", "call", "custom-call", "map"):
            m = _CALLS.search(ins.attrs) or re.search(r"to_apply=%?([\w.\-]+)",
                                                      ins.attrs)
            if m and m.group(1) in self.comps:
                # fusion internals: flops yes, boundary bytes already counted
                cost.add(self.comp_cost(m.group(1), fused=True))


def analyze_hlo_text(text: str, n_devices: int,
                     bf16_normalize: bool = True) -> dict:
    """``bf16_normalize``: project CPU-backend f32-upcast dots/collectives
    back to their TPU-native bf16 sizes (see DESIGN.md §8 notes).  Genuine
    f32 tensors (optimizer moments, CE) are halved too — a <=2x error on
    terms that are <1% of traffic in these models."""
    comps = parse_hlo(text)
    walker = CostWalker(comps, n_devices, bf16_normalize=bf16_normalize)
    entry = None
    for name in comps:
        if "main" in name:
            entry = name
    c = walker.total(entry)
    top_bytes = dict(sorted(c.bytes_by_op.items(), key=lambda kv: -kv[1])[:8])
    top_flops = dict(sorted(c.flops_by_op.items(), key=lambda kv: -kv[1])[:8])
    return {
        "flops_per_device": c.flops,
        "bytes_per_device": c.bytes,
        "coll_bytes_per_device": c.coll_bytes,
        "coll_counts": {k: {"count": v[0], "bytes": v[1]}
                        for k, v in c.coll_counts.items()},
        "bytes_by_op": top_bytes,
        "flops_by_op": top_flops,
        "n_computations": len(comps),
        "while_trip_counts": walker.trip_counts,
    }
