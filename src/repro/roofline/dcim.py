"""DCIM serving roofline: close the compiler -> serving loop.

The multi-spec synthesis engine picks a macro per deployed workload
(:func:`repro.serve.select.select_macros`); this module answers "how fast does
the deployment actually serve on it?".  Macro wallclock alone overstates
throughput: the macro array only computes as fast as HBM can stream
activations in and results out (weights are resident, that's the point of
CIM — but the act/psum traffic still pays the memory wall).  The serving
bound is the classic two-term roofline

    bound_s = max(t_macro / kernel_fraction, t_hbm)

where ``t_macro`` is the co-design matrix's wallclock for the workload's GEMM
inventory on the selected macro (already clamped to the reporting frequency),
and ``t_hbm`` streams the inventory's activation/output bytes plus one weight
residency load through :data:`repro.roofline.hw.HBM_BW`.

``kernel_fraction`` closes the loop against *measurement*: the analytic
compute term assumes the execution kernels perfectly overlap operand
streaming with arithmetic.  The DMA/compute profiling harness
(:mod:`repro.kernels.profile`) measures how true that is — its
``roofline_fraction`` is the share of fused kernel time the slower pipeline
side accounts for.  Feeding the measured fraction (e.g. via
``fraction_from_profiles``) derates the compute term, turning the ideal
roofline into a measured-pipeline-efficiency roofline.  The default 1.0
keeps every existing caller's numbers bit-identical.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence

from . import hw

#: Accumulator output width in bytes streamed back per output element (the
#: OFU emits sign-extended partial sums; 4 B covers every supported mode).
_OUT_BYTES = 4


@dataclass(frozen=True)
class DcimServingEstimate:
    """Roofline-bounded serving estimate for one (workload, macro) pair."""

    workload: str
    macro: str
    tokens: int                # tokens per model step (the GEMM m dim)
    t_macro_s: float           # macro-array compute wallclock per step
    t_hbm_s: float             # HBM streaming time per step
    bound_s: float             # max of the two — the serving step time
    tokens_per_s: float        # roofline-bounded serving throughput
    bottleneck: str            # "macro-compute" | "hbm"
    kernel_fraction: float = 1.0   # measured pipeline efficiency applied

    def summary(self) -> dict:
        out = {
            "workload": self.workload, "macro": self.macro,
            "tokens": self.tokens,
            "t_macro_ms": round(self.t_macro_s * 1e3, 4),
            "t_hbm_ms": round(self.t_hbm_s * 1e3, 4),
            "tokens_per_s": round(self.tokens_per_s, 1),
            "bottleneck": self.bottleneck,
        }
        if self.kernel_fraction != 1.0:
            out["kernel_fraction"] = round(self.kernel_fraction, 4)
        return out


def inventory_bytes(gemms: Sequence, ib: int = 8, wb: int = 8
                    ) -> tuple[float, float]:
    """(activation+output bytes, weight bytes) one model step moves over HBM.

    Activations stream in at the serving precision (``ib`` bits), outputs
    stream back at accumulator width; weights are loaded once per step for
    residency — ``count`` scales both terms, since each GEMM instance (e.g.
    one decoder layer's wq) owns distinct weights (weight-stationary
    mapping — reload churn beyond residency is already priced into the macro
    wallclock by the co-design matrix)."""
    act = sum(g.count * (g.m * g.k * ib / 8 + g.m * g.n * _OUT_BYTES)
              for g in gemms)
    wt = sum(g.count * g.k * g.n * wb / 8 for g in gemms)
    return float(act), float(wt)


def dcim_serving_bound(gemms: Sequence, wallclock_s: float, ib: int = 8,
                       wb: int = 8, workload: str = "", macro: str = "",
                       kernel_fraction: float = 1.0) -> DcimServingEstimate:
    """Two-term serving roofline for one workload on its selected macro.

    ``wallclock_s`` is the co-design wallclock of the workload's GEMM
    inventory on the macro array (:class:`repro.core.dse.CodesignReport`),
    i.e. the compute term; the memory term streams the inventory's bytes
    through the HBM bandwidth of :mod:`repro.roofline.hw`.

    ``kernel_fraction`` in (0, 1] derates the compute term by the measured
    pipeline efficiency of the execution kernels (see
    :func:`repro.kernels.profile.fraction_from_profiles` — or pass any
    measured fraction).  1.0 (the default) is the ideal-overlap roofline."""
    if not gemms:
        raise ValueError("need a non-empty GEMM inventory")
    if not 0.0 < kernel_fraction <= 1.0:
        raise ValueError(f"kernel_fraction must be in (0, 1], "
                         f"got {kernel_fraction}")
    tokens = max(g.m for g in gemms)
    act_bytes, wt_bytes = inventory_bytes(gemms, ib, wb)
    t_hbm = (act_bytes + wt_bytes) / hw.HBM_BW
    t_macro = float(wallclock_s) / kernel_fraction
    bound = max(t_macro, t_hbm)
    tps = tokens / bound if bound > 0 else math.inf
    return DcimServingEstimate(
        workload=workload, macro=macro, tokens=tokens,
        t_macro_s=t_macro, t_hbm_s=t_hbm, bound_s=bound,
        tokens_per_s=tps,
        bottleneck="macro-compute" if t_macro >= t_hbm else "hbm",
        kernel_fraction=kernel_fraction)
