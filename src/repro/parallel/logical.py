"""Logical-axis parameter trees.

Model init functions build pytrees of :class:`Logical` leaves — an array (or
ShapeDtypeStruct during abstract init) tagged with *logical* axis names
("embed", "heads", "ff", "experts", ...).  :func:`split_logical` separates the
tree into (values, PartitionSpecs) given the logical->mesh rules in
``repro.parallel.sharding``; the specs drive pjit in/out shardings so the same
model definition runs on any mesh.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class Logical(NamedTuple):
    value: Any                       # jnp.ndarray | ShapeDtypeStruct
    axes: tuple[str | None, ...]     # one logical name (or None) per dim


def param(key, shape: tuple[int, ...], axes: tuple[str | None, ...],
          dtype=jnp.float32, init: str = "normal", scale: float | None = None
          ) -> Logical:
    """Create an initialized, logically-tagged parameter."""
    assert len(shape) == len(axes), (shape, axes)
    if init == "zeros":
        v = jnp.zeros(shape, dtype)
    elif init == "ones":
        v = jnp.ones(shape, dtype)
    else:
        fan_in = shape[0] if len(shape) > 1 else max(shape[-1], 1)
        s = scale if scale is not None else fan_in ** -0.5
        v = (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)
    return Logical(v, tuple(axes))


def is_logical(x) -> bool:
    return isinstance(x, Logical)


def split_logical(tree, rules: dict[str, Any]):
    """(tree of Logical) -> (tree of arrays, tree of PartitionSpec)."""
    from jax.sharding import PartitionSpec as P

    def val(leaf):
        return leaf.value

    def spec(leaf):
        return P(*(rules.get(a, None) if a is not None else None
                   for a in leaf.axes))

    values = jax.tree.map(val, tree, is_leaf=is_logical)
    specs = jax.tree.map(spec, tree, is_leaf=is_logical)
    return values, specs


def spec_of(tree, rules: dict[str, Any]):
    return split_logical(tree, rules)[1]


def values_of(tree):
    """Strip Logical wrappers -> plain array tree (jit-traceable)."""
    return jax.tree.map(lambda l: l.value if is_logical(l) else l, tree,
                        is_leaf=is_logical)


_AXIS_SEP = "\x1f"
_NONE_AXIS = "\x00"


def abstract_init(init_fn, *args):
    """Trace ``init_fn`` (a Logical-tree builder) without allocating anything:
    returns a Logical tree whose values are ShapeDtypeStructs.

    Axes are static metadata; they're smuggled out of the eval_shape trace as
    encoded strings (strings are pytree *leaves* in JAX)."""
    box = {}

    def run(*a):
        tree = init_fn(*a)
        box["axes"] = jax.tree.map(
            lambda l: _AXIS_SEP.join(x if x is not None else _NONE_AXIS
                                     for x in l.axes),
            tree, is_leaf=is_logical)
        return values_of(tree)

    vals = jax.eval_shape(run, *args)
    axes_tree = box["axes"]

    def rewrap(v, enc):
        axes = tuple(None if a == _NONE_AXIS else a
                     for a in enc.split(_AXIS_SEP)) if enc else ()
        return Logical(v, axes)

    return jax.tree.map(rewrap, vals, axes_tree)
