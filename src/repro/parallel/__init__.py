from .logical import Logical, param, split_logical, spec_of
from .sharding import (MESH_RULES, logical_to_spec, shard_batch_spec,
                       with_sharding)

__all__ = ["Logical", "param", "split_logical", "spec_of", "MESH_RULES",
           "logical_to_spec", "shard_batch_spec", "with_sharding"]
