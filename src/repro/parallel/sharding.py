"""Logical-axis -> mesh-axis rules (the distribution strategy).

Default layout is 2-D FSDP x TP (GSPMD/ZeRO-3 style), proven at 256-512 chips
and the standard layout for this scale (MaxText/GSPMD lineage):

  * ``embed`` (the d_model dim of weight matrices)  -> sharded over ``data``
    — this is the FSDP/ZeRO-3 axis: XLA all-gathers each layer's weights just
    before use and reduce-scatters gradients, so per-chip parameter+optimizer
    memory divides by |data| (123B fits; see DESIGN.md §6).
  * ``heads`` / ``ff`` / ``experts`` / ``vocab``     -> sharded over ``model``
    — the tensor/expert-parallel axis.
  * ``batch``  -> ('pod', 'data'): pure data parallelism across pods.
  * ``kv_seq`` -> 'data' for long-context cached decode (sequence parallel).

`layer` (the scan axis over stacked per-layer params) is never sharded.
Alternative layouts used by the perf hillclimb are expressed as rule
overrides per arch config (``cfg.sharding_overrides``).
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# Logical axis -> mesh axis (or tuple of mesh axes).
MESH_RULES: dict[str, Any] = {
    # weights
    "embed": "data",          # FSDP / ZeRO-3 axis
    "embed_no_fsdp": None,
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "experts": "model",
    "expert_ff": None,
    "vocab": "model",
    "layer": None,
    "conv": None,
    "state": None,
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    # KV caches carry (batch, kv_seq, cache_heads) together: batch takes the
    # data axes, the cache sequence dim takes 'model' (context parallelism),
    # heads stay local (GQA head counts rarely divide 16):
    "kv_seq": "model",
    "cache_heads": None,
    "act_embed": None,
    # K/V tensors entering blockwise attention: force the (possibly
    # seq-sharded) K/V to gather ONCE per layer instead of once per q-block
    # (sequence-parallel prefill, §Perf iteration 3):
    "attn_kv_seq": None,
    "act_heads": "model",
    "act_ff": "model",
    "act_vocab": "model",
    # DCIM compiler sweeps: the stacked macro-spec axis of the multi-spec
    # synthesis engine (repro.core.shardspec) — one lane per spec, sharded
    # across whatever devices the sweep mesh exposes:
    "spec": "spec",
}


def spec_sweep_mesh(devices=None) -> Mesh:
    """1-D ('spec',) mesh over the given (default: all) devices — the
    placement the sharded multi-spec engine hands to ``rules_for_mesh``.
    Built with the plain Mesh constructor so it works on every jax the repo
    supports (``jax.make_mesh`` axis types are not needed: the engine's
    kernel is embarrassingly parallel along the spec axis)."""
    import numpy as _np
    if devices is None:
        devices = jax.devices()
    return Mesh(_np.asarray(devices), ("spec",))


def host_spec_mesh(devices=None, n_hosts: int | None = None) -> Mesh:
    """2-D ('host', 'spec') mesh: one mesh axis per host, the per-host
    devices along 'spec' — the placement of the engine's multi-host strategy
    (:mod:`repro.core.multihost`).  ``n_hosts`` defaults to
    ``jax.process_count()``; on a single-host runtime the host axis has
    length 1 and the mesh degenerates to the single-host spec sweep (same
    device set, same partitioning of the stacked spec axis)."""
    import numpy as _np
    if devices is None:
        devices = jax.devices()
    devs = _np.asarray(devices)
    if n_hosts is None:
        n_hosts = jax.process_count() if hasattr(jax, "process_count") else 1
    if n_hosts < 1 or devs.size % n_hosts:
        n_hosts = 1            # ragged host split: fall back to one host row
    return Mesh(devs.reshape(n_hosts, -1), ("host", "spec"))


def rules_for_mesh(mesh: Mesh, overrides: dict[str, Any] | None = None
                   ) -> dict[str, Any]:
    """Drop mesh axes that don't exist (e.g. 'pod' on the single-pod mesh)."""
    names = set(mesh.axis_names)
    out = {}
    merged = dict(MESH_RULES)
    if overrides:
        merged.update(overrides)
    for k, v in merged.items():
        if isinstance(v, list):
            v = tuple(v)
        if isinstance(v, tuple):
            kept = tuple(a for a in v if a in names)
            out[k] = kept if len(kept) > 1 else (kept[0] if kept else None)
        else:
            out[k] = v if (v is None or v in names) else None
    return out


def logical_to_spec(axes: tuple[str | None, ...], rules: dict[str, Any]) -> P:
    return P(*(rules.get(a, None) if a is not None else None for a in axes))


def shard_batch_spec(rules: dict[str, Any]) -> P:
    return P(rules.get("batch"), None)


def with_sharding(x, mesh: Mesh, spec: P):
    """Sharding constraint helper (no-op outside jit on un-committed arrays)."""
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Activation sharding constraints (the §Perf iteration-1 fix)
# ---------------------------------------------------------------------------
#
# Without explicit activation constraints GSPMD may satisfy the d_in('data')-
# sharded weight contraction by partial-summing and ALL-REDUCING full
# activations (measured: 3.6 TiB/chip/step on llama train_4k) instead of
# all-gathering the (much smaller) FSDP-sharded weights.  Constraining every
# linear's output to (batch->data axes, seq local, features->model-if-TP)
# forces the weight-gather strategy.  Enabled per-arch via cfg.act_shard.

import contextvars

_ACT_RULES: contextvars.ContextVar = contextvars.ContextVar("act_rules",
                                                            default=None)


def activation_rules(rules: dict[str, Any] | None):
    """Set the ambient logical->mesh rules used by constrain_act.  Returns a
    reset token for ``reset_activation_rules``."""
    return _ACT_RULES.set(rules)


def reset_activation_rules(token) -> None:
    _ACT_RULES.reset(token)


def constrain_act(x, axes: tuple[str | None, ...]):
    """Constrain an activation to the ambient rules (no-op when unset or when
    rank mismatches / no mesh context is active)."""
    rules = _ACT_RULES.get()
    if rules is None or len(axes) != x.ndim:
        return x
    spec = P(*[rules.get(a) if a is not None else None for a in axes])
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x
