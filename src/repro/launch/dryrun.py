import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes with 512 placeholder host devices — proving the sharding
config is coherent without hardware — and extract the roofline terms.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b \
        --shape train_4k --mesh single --out artifacts/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Each cell runs in-process; the --all driver spawns one subprocess per cell
(compiles are memory-hungry and XLA flags are per-process).
"""

import argparse        # noqa: E402
import json            # noqa: E402
import subprocess      # noqa: E402
import sys             # noqa: E402
import time            # noqa: E402
from pathlib import Path  # noqa: E402

import jax             # noqa: E402
import jax.numpy as jnp   # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs import SHAPES, applicable_shapes, get_config, list_archs  # noqa: E402
from ..models import get_model                       # noqa: E402
from ..optim.schedules import constant_lr            # noqa: E402
from ..parallel.sharding import rules_for_mesh       # noqa: E402
from ..roofline.hlo_parse import analyze_hlo_text    # noqa: E402
from ..train.step import make_train_step             # noqa: E402
from . import specs as S                             # noqa: E402
from .mesh import make_production_mesh               # noqa: E402


def _memory_analysis(compiled) -> dict:
    out = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                out[attr] = int(v)
        out["total_bytes"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0))
    except Exception as e:  # CPU backend may not implement everything
        out["error"] = str(e)
    return out


def _cost_analysis(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and
                ("flops" in k or "bytes accessed" == k or "utilization" in k)}
    except Exception as e:
        return {"error": str(e)}


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             microbatches: int = 1, overrides: dict | None = None,
             hlo_out: Path | None = None, tuned: bool = False) -> dict:
    cfg = get_config(arch)
    if tuned:
        from .tuned import tuned_overrides
        # act_shard pays for train/prefill (weight-gather vs activation
        # all-reduce); decode steps are cache-read bound and the constraints
        # on (B,1,d) tensors only add resharding — measured 0.5-0.9x.
        want_act = SHAPES[shape_name].kind != "decode"
        merged = {"act_shard": want_act, **tuned_overrides(arch, shape_name,
                                                           mesh_kind)}
        merged.update(overrides or {})
        overrides = merged
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    api = get_model(cfg)
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    n_dev = mesh.devices.size
    shape_rules = dict(cfg.sharding_overrides)
    if shape.global_batch == 1:
        # long_500k: batch of 1 cannot shard; spread the cached sequence over
        # every mesh axis instead (context parallelism at 500k tokens).
        shape_rules.setdefault("batch", None)
        shape_rules.setdefault("kv_seq",
                               ("pod", "data", "model") if multi
                               else ("data", "model"))
        shape_rules.setdefault("act_heads", "model")
    rules = rules_for_mesh(mesh, shape_rules)

    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "devices": int(n_dev), "kind": shape.kind,
           "params": cfg.param_count(),
           "active_params": cfg.active_param_count(),
           "act_shard": cfg.act_shard, "overrides": overrides or {}}
    t0 = time.time()

    from ..parallel.sharding import activation_rules, reset_activation_rules
    tok = activation_rules(rules if cfg.act_shard else None)
    try:
        return _run_cell_inner(cfg, api, mesh, rules, shape, rec, t0,
                               microbatches, hlo_out)
    finally:
        reset_activation_rules(tok)


def _run_cell_inner(cfg, api, mesh, rules, shape, rec, t0, microbatches,
                    hlo_out):
    n_dev = rec["devices"]
    with mesh:
        if shape.kind == "train":
            params, p_shard = S.abstract_params(api, mesh, rules)
            opt, o_shard = S.abstract_opt_state(params, p_shard, mesh)
            batch = S.train_batch_specs(cfg, shape)
            b_shard = S.batch_shardings(cfg, batch, mesh, rules)
            step = make_train_step(api, constant_lr(1e-4),
                                   microbatches=microbatches)
            jitted = jax.jit(step,
                             in_shardings=(p_shard, o_shard, b_shard),
                             out_shardings=(p_shard, o_shard, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params, opt, batch)
        elif shape.kind == "prefill":
            params, p_shard = S.abstract_params(api, mesh, rules)
            toks, t_shard = S.prefill_token_specs(cfg, shape, mesh, rules)
            fe = None
            fe_shard = None
            if cfg.frontend is not None:
                fe = S.sds((shape.global_batch, cfg.frontend.n_tokens,
                            cfg.frontend.d_frontend), jnp.float32)
                fe_shard = NamedSharding(mesh, P(rules.get("batch"), None, None))

            def prefill_step(p, t, f=None):
                return api.prefill(p, t, shape.seq_len, frontend=f)

            if fe is None:
                jitted = jax.jit(lambda p, t: prefill_step(p, t),
                                 in_shardings=(p_shard, t_shard))
                lowered = jitted.lower(params, toks)
            else:
                jitted = jax.jit(prefill_step,
                                 in_shardings=(p_shard, t_shard, fe_shard))
                lowered = jitted.lower(params, toks, fe)
        else:  # decode
            params, p_shard = S.abstract_params(api, mesh, rules)
            state, st_shard = S.abstract_decode_state(api, shape, mesh, rules)
            toks, t_shard = S.decode_token_specs(cfg, shape, mesh, rules)

            def decode(p, st, t):
                return api.decode_step(p, st, t)

            jitted = jax.jit(decode,
                             in_shardings=(p_shard, st_shard, t_shard),
                             out_shardings=(None, st_shard),
                             donate_argnums=(1,))
            lowered = jitted.lower(params, state, toks)

        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

    mem = _memory_analysis(compiled)
    # On the forced-host platform memory_analysis aggregates across all
    # partitions (verified: whisper train temp / 256 == the per-device f32
    # logits+CE buffer exactly); normalize to per-device.
    if "total_bytes" in mem:
        mem["temp_bytes_per_device"] = mem.get("temp_size_in_bytes", 0) // n_dev
        mem["args_bytes_per_device"] = mem.get("argument_size_in_bytes", 0) // n_dev
        mem["total_bytes_per_device"] = mem["total_bytes"] // n_dev
    rec["memory"] = mem
    rec["xla_cost"] = _cost_analysis(compiled)
    t2 = time.time()
    hlo = compiled.as_text()
    rec["hlo_bytes"] = len(hlo)
    rec["cost"] = analyze_hlo_text(hlo, n_dev)
    rec["analyze_s"] = round(time.time() - t2, 1)
    if hlo_out is not None:
        import gzip
        with gzip.open(hlo_out, "wt") as f:
            f.write(hlo)
    rec["ok"] = True
    return rec


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def all_cells(mesh_kinds: list[str]) -> list[tuple[str, str, str]]:
    cells = []
    for arch in list_archs():
        cfg = get_config(arch)
        for shape in applicable_shapes(cfg):
            for mk in mesh_kinds:
                cells.append((arch, shape, mk))
    return cells


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (hillclimb knobs)")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--tuned", action="store_true",
                    help="apply §Perf tuned overrides (act_shard + tuned.py)")
    ap.add_argument("--timeout", type=int, default=3000)
    args = ap.parse_args()

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    if args.all:
        kinds = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        cells = all_cells(kinds)
        failures = 0
        for arch, shape, mk in cells:
            tag = f"{arch}__{shape}__{mk}"
            path = outdir / f"{tag}.json"
            if path.exists() and json.loads(path.read_text()).get("ok"):
                print(f"[skip] {tag} (cached)")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
                   "--shape", shape, "--mesh", mk, "--out", str(outdir)]
            if args.tuned:
                cmd.append("--tuned")
            print(f"[run ] {tag}", flush=True)
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout,
                               env={**os.environ, "PYTHONPATH": "src"})
            if r.returncode != 0:
                failures += 1
                path.write_text(json.dumps({
                    "arch": arch, "shape": shape, "mesh": mk, "ok": False,
                    "error": r.stderr[-4000:]}, indent=1))
                print(f"[FAIL] {tag}\n{r.stderr[-2000:]}")
        print(f"done; failures={failures}")
        return 1 if failures else 0

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v

    kinds = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for mk in kinds:
        tag = f"{args.arch}__{args.shape}__{mk}"
        rec = run_cell(args.arch, args.shape, mk,
                       microbatches=args.microbatches,
                       overrides=overrides or None,
                       hlo_out=outdir / f"{tag}.hlo.gz", tuned=args.tuned)
        path = outdir / f"{tag}.json"
        path.write_text(json.dumps(rec, indent=1))
        mem = rec.get("memory", {})
        print(json.dumps({k: rec[k] for k in
                          ("arch", "shape", "mesh", "compile_s")}, ))
        print("memory_analysis:", {k: v for k, v in mem.items()})
        print("cost_analysis(xla):", rec.get("xla_cost"))
        print("cost(walker):", {k: v for k, v in rec["cost"].items()
                                if k != "while_trip_counts"})
    return 0


if __name__ == "__main__":
    sys.exit(main())
