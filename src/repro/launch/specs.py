"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape) cell.

``input_specs(cfg, shape)`` returns weak-type-correct, shardable SDS trees for
each step kind — no device allocation, the dry-run lowers directly from
these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig, ShapeCfg
from ..models.registry import ModelApi
from ..parallel.logical import abstract_init, split_logical


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ArchConfig, shape: ShapeCfg):
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": sds((b, s), jnp.int32), "labels": sds((b, s), jnp.int32)}
    if cfg.frontend is not None:
        batch["frontend"] = sds((b, cfg.frontend.n_tokens,
                                 cfg.frontend.d_frontend), jnp.float32)
    return batch


def batch_shardings(cfg: ArchConfig, batch, mesh, rules):
    bspec = rules.get("batch")

    def spec_for(x):
        return NamedSharding(mesh, P(bspec, *([None] * (len(x.shape) - 1))))

    return jax.tree.map(spec_for, batch)


def abstract_params(api: ModelApi, mesh, rules):
    """(SDS tree, NamedSharding tree) for the model params — no allocation."""
    key = jax.random.PRNGKey(0)
    ltree = abstract_init(api.init_params, key)
    vals, specs = split_logical(ltree, rules)
    shardings = jax.tree.map(lambda sp: NamedSharding(mesh, sp), specs)
    return vals, shardings


def abstract_opt_state(params_sds, param_shardings, mesh):
    """AdamW m/v mirror the params (f32); count replicated."""
    f32 = lambda p: sds(p.shape, jnp.float32)
    return (
        {"m": jax.tree.map(f32, params_sds),
         "v": jax.tree.map(f32, params_sds),
         "count": sds((), jnp.int32)},
        {"m": param_shardings, "v": param_shardings,
         "count": NamedSharding(mesh, P())},
    )


def abstract_decode_state(api: ModelApi, shape: ShapeCfg, mesh, rules):
    """(SDS tree, shardings) for the serve state: KV cache of seq_len (the
    'one new token against a cache of seq_len' contract)."""
    b, s = shape.global_batch, shape.seq_len
    ltree = abstract_init(lambda: api.init_decode_state(b, s))
    vals, specs = split_logical(ltree, rules)
    shardings = jax.tree.map(lambda sp: NamedSharding(mesh, sp), specs)
    return vals, shardings


def decode_token_specs(cfg: ArchConfig, shape: ShapeCfg, mesh, rules):
    b = shape.global_batch
    toks = sds((b, 1), jnp.int32)
    shard = NamedSharding(mesh, P(rules.get("batch"), None))
    return toks, shard


def prefill_token_specs(cfg: ArchConfig, shape: ShapeCfg, mesh, rules):
    b, s = shape.global_batch, shape.seq_len
    toks = sds((b, s), jnp.int32)
    shard = NamedSharding(mesh, P(rules.get("batch"), None))
    return toks, shard
