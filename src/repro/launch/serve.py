"""Batched serving launcher: prefill + continuous greedy decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
        --batch 4 --prompt-len 64 --decode-steps 64 --mesh 1x1

``--dcim-select`` adds the serving-time macro-selection step: the launcher
synthesizes the multi-spec DCIM frontier through the online synthesis
service (one fused, cached pass over the scenario specs, submitted as
typed INTERACTIVE requests), co-designs it against the deployed arch's GEMM
inventory, and reports the macro the workload would be served on.
``--dcim-cache PATH`` points the service at a persistent frontier store,
making the second launch warm (zero engine executions); ``--dcim-profile
PATH`` round-trips the preference-profile artifact through
:func:`repro.serve.select.apply_profile`.

The ``--dcim-*`` flag cluster is one typed posture,
:class:`repro.serve.config.ServeConfig`: ``--dcim-config PATH`` loads it
from a JSON artifact and every explicitly-passed flag overrides the file.

``--dcim-trace PATH`` turns on :mod:`repro.obs` request tracing for the
launch: the selection pass runs through a :class:`repro.service.
ServiceFrontend` (so every request carries real queued -> batched ->
served timestamps) and a Chrome-trace JSON lands at PATH — load it at
``ui.perfetto.dev`` to see the span tree from request admission through
cache tiers to the fused engine pass.  ``--dcim-kernel-profile PATH``
feeds a measured ``scripts/profile_kernels.py --json`` artifact into the
serving roofline (``kernel_fraction`` derate), closing the loop between
profiled pipeline efficiency and the reported tokens/s bound.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from ..configs import get_config, smoke_config
from ..models import get_model
from ..parallel.logical import split_logical
from ..parallel.sharding import rules_for_mesh
from ..serve import make_decode_step, make_prefill
from ..serve.config import serve_config_from_args
from .mesh import make_host_mesh
from .train import parse_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--decode-steps", type=int, default=64)
    ap.add_argument("--dcim-config", default=None, metavar="PATH",
                    help="JSON ServeConfig artifact consolidating the "
                         "--dcim-* posture (schema "
                         "syndcim-serve-config/v1); explicit --dcim-* "
                         "flags override the file")
    ap.add_argument("--dcim-select", action="store_true",
                    help="select a DCIM macro for this workload from the "
                         "multi-spec synthesized frontier before serving")
    ap.add_argument("--dcim-macros", type=int, default=None,
                    help="macro-array size assumed for --dcim-select "
                         "(default 256)")
    ap.add_argument("--dcim-pref", default=None, metavar="W,E,A",
                    help="preference weights wallclock,energy,area for "
                         "--dcim-select (e.g. 0.2,0.6,0.2); default: pure "
                         "wallclock")
    ap.add_argument("--dcim-profile", default=None, metavar="PATH",
                    help="JSON preference-profile artifact persisted per "
                         "deployment config: read before --dcim-select "
                         "(profile weights for this arch override "
                         "--dcim-pref) and updated afterwards with the "
                         "weights the selection ran under")
    ap.add_argument("--dcim-cache", default=None, metavar="PATH",
                    help="persistent frontier-cache directory for the "
                         "synthesis service: the first --dcim-select launch "
                         "writes the synthesized scenario frontiers there, "
                         "every later launch serves them with zero engine "
                         "executions")
    ap.add_argument("--dcim-registry", default=None, metavar="PATH",
                    help="fleet-shared artifact-registry root (a directory "
                         "on shared storage): frontiers synthesized by ANY "
                         "host land there, so every other host's "
                         "--dcim-select launch is warm; claim files keep "
                         "concurrent cold launches from synthesizing the "
                         "same spec twice (see scripts/warm_cache.py to "
                         "pre-fill it ahead of a deployment)")
    ap.add_argument("--dcim-trace", default=None, metavar="PATH",
                    help="enable request tracing and write a Chrome-trace "
                         "JSON (ui.perfetto.dev) of the launch: per-request "
                         "queued/batched/served spans, cache-tier probes, "
                         "engine phases, kernel dispatches")
    ap.add_argument("--dcim-trace-sample", type=float, default=None,
                    metavar="F", help="head sampling rate for --dcim-trace "
                                      "in (0, 1] (default 1.0)")
    ap.add_argument("--dcim-kernel-profile", default=None, metavar="PATH",
                    help="kernel-profile artifact from scripts/"
                         "profile_kernels.py --json: its measured pipeline "
                         "efficiency derates the serving roofline "
                         "(kernel_fraction)")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    dcim = serve_config_from_args(args)
    if dcim.trace is not None:
        from .. import obs
        obs.configure(enabled=True, sample=dcim.trace_sample)
    kernel_fraction = 1.0
    if dcim.kernel_profile is not None:
        from ..kernels.profile import fraction_from_profile_artifact
        kernel_fraction = fraction_from_profile_artifact(
            dcim.kernel_profile)
        print(f"dcim: kernel profile {dcim.kernel_profile}: serving "
              f"roofline derated by measured pipeline efficiency "
              f"{kernel_fraction:.3f}")
    if dcim.select:
        from ..core.dse import gemm_inventory
        from ..serve.select import apply_profile, select_macros
        from ..service import (ArtifactRegistry, FrontierCache,
                               SynthesisService, get_service)
        if dcim.cache is not None or dcim.registry is not None:
            registry = (None if dcim.registry is None
                        else ArtifactRegistry(dcim.registry))
            service = SynthesisService(
                cache=FrontierCache(store_dir=dcim.cache,
                                    registry=registry))
        else:
            service = get_service()
        serve_via = service
        frontend = None
        if dcim.trace is not None:
            # Route the selection pass through the admission frontend so
            # every traced request carries real queued -> batched ->
            # served timestamps (the span boundaries the trace shows).
            from ..service import ServiceFrontend
            frontend = ServiceFrontend(service)
            serve_via = frontend
        sel, _ = apply_profile(
            dcim.profile,
            lambda profile: select_macros({cfg.name: gemm_inventory(cfg)},
                                          n_macros=dcim.macros,
                                          preference=dcim.pref,
                                          profile=profile,
                                          service=serve_via,
                                          kernel_fraction=kernel_fraction))
        if frontend is not None:
            frontend.close()
        if dcim.profile is not None:
            print(f"dcim: preference profile updated: {dcim.profile}")
        cs, ss = service.cache.stats, service.stats
        print(f"dcim: synthesis service "
              f"{'warm' if ss.misses == 0 else 'cold'} "
              f"(hits={cs.hits + cs.disk_hits + cs.shared_hits} "
              f"misses={ss.misses} fused_passes={ss.fused_passes}"
              + (f", cache={dcim.cache}" if dcim.cache else "")
              + ")")
        if dcim.registry is not None:
            rt = service.cache.registry.telemetry()
            print(f"dcim: shared registry {dcim.registry}: "
                  f"{rt['entries']} entries, "
                  f"hits={rt['hits']} misses={rt['misses']} "
                  f"fills={rt['fills']} "
                  f"claims={rt['claims_acquired']}"
                  f"/{ss.claim_waits} waited"
                  f"/{ss.claim_hits} served-by-peer")
        wi = sel.codesign.workloads.index(cfg.name)
        di = sel.assignment[cfg.name]
        est = sel.serving_for(cfg.name)
        applied = sel.preferences_applied[cfg.name]
        print(f"dcim: {len(sel.pool)} frontier candidates from scenarios "
              f"{', '.join(sel.scenarios)}"
              + (f", preference={applied}" if applied else ""))
        print(f"dcim: selected {sel.label_for(cfg.name)} for {cfg.name} "
              f"({dcim.macros} macros, "
              f"eff_tops={sel.codesign.effective_tops[wi, di]:.3f}, "
              f"util={sel.codesign.avg_util[wi, di]:.3f})")
        print(f"dcim: serving roofline {est.tokens_per_s:.1f} tok/s "
              f"({est.bottleneck}-bound: macro {est.t_macro_s * 1e3:.3f} ms "
              f"vs hbm {est.t_hbm_s * 1e3:.3f} ms per "
              f"{est.tokens}-token step)")
    api = get_model(cfg)
    dims, axes = parse_mesh(args.mesh)
    mesh = make_host_mesh(dims, axes)
    rules = rules_for_mesh(mesh, cfg.sharding_overrides)

    params_l = api.init_params(jax.random.PRNGKey(0))
    params, specs = split_logical(params_l, rules)
    params = jax.device_put(params, jax.tree.map(
        lambda sp: NamedSharding(mesh, sp), specs))

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab,
                                       (args.batch, args.prompt_len)))
    frontend = None
    if cfg.frontend is not None:
        frontend = jnp.asarray(rng.normal(size=(
            args.batch, cfg.frontend.n_tokens, cfg.frontend.d_frontend)),
            jnp.float32)

    cache_len = args.prompt_len + args.decode_steps
    prefill = jax.jit(make_prefill(api, cache_len))
    decode = jax.jit(make_decode_step(api), donate_argnums=(1,))

    with mesh:
        t0 = time.time()
        logits, state = prefill(params, prompts, frontend)
        jax.block_until_ready(logits)
        t_prefill = time.time() - t0
        tok = jnp.argmax(logits[:, -1:], axis=-1)
        out = [tok]
        t1 = time.time()
        for _ in range(args.decode_steps - 1):
            logits, state = decode(params, state, tok)
            tok = jnp.argmax(logits[:, -1:], axis=-1)
            out.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.time() - t1

    n_tok = args.batch * args.decode_steps
    print(f"arch={cfg.name} mesh={mesh.shape}")
    print(f"prefill: {args.batch}x{args.prompt_len} tokens in "
          f"{t_prefill:.2f}s")
    print(f"decode : {n_tok} tokens in {t_decode:.2f}s "
          f"({n_tok / max(t_decode, 1e-9):.1f} tok/s)")
    print("sample:", np.asarray(jnp.concatenate(out, axis=1))[0, :16])

    if dcim.trace is not None:
        from ..obs import tracer
        from ..obs.export import write_chrome_trace
        n = write_chrome_trace(tracer.drain(), dcim.trace)
        print(f"dcim: trace: {n} span events -> {dcim.trace} "
              f"(load at ui.perfetto.dev or chrome://tracing)")


if __name__ == "__main__":
    main()
