"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips ('data','model');
multi-pod: 2x16x16 = 512 chips ('pod','data','model') — the 'pod' axis is
pure data parallelism across DCN.
"""

from __future__ import annotations

import jax


def _mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """``jax.make_mesh`` with Auto axis types where the runtime has them.

    Capability is detected with ``hasattr`` — never a version pin — so the
    same call works on the pinned jax 0.4.37 (whose ``make_mesh`` takes no
    ``axis_types`` and whose ``jax.sharding`` has no ``AxisType``) and
    un-gates automatically on newer jax."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh(shape: tuple[int, ...] = (1, 1),
                   axes: tuple[str, ...] = ("data", "model")):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = 1
    for s in shape:
        n *= s
    avail = len(jax.devices())
    if n > avail:
        shape = (1,) * (len(shape) - 1) + (avail,)
    return _mesh(shape, axes)
