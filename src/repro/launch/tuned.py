"""Per-cell tuned configurations — the OUTCOME of the §Perf hillclimb
(EXPERIMENTS.md).  ``--tuned`` dry-runs apply these on top of
``act_shard=True`` (iteration 1, global win) to produce the beyond-paper
optimized table; baselines stay in artifacts/dryrun.

Keys: (arch, shape, mesh) with None wildcards; first exact match wins.
"""

from __future__ import annotations

# Tiny-width archs (d_model/16 < 128 lanes) suffer degenerate 16-way TP:
# head-padding all-reduces dominate.  Measured fixes:
#   * train (global_batch divides the whole mesh): pure data parallelism —
#     batch over every axis, features local, weights still FSDP-sharded.
#   * prefill (batch < chips): batch over 'data' + sequence-parallel
#     activations over 'model'.
_PURE_DP_TRAIN = {"batch": ["pod", "data", "model"], "act_heads": None,
                  "act_ff": None}
_PURE_DP_TRAIN_MULTI = {"batch": ["data", "model"], "act_heads": None,
                        "act_ff": None}
_SEQ_PARALLEL = {"seq": "model", "act_heads": None, "act_ff": None}

TUNED: dict[tuple, dict] = {
    # mistral-large-123b train: remat off — recompute eliminated (compute
    # 20.0s -> 16.0s, memory 19.1 -> 14.7s, mfu bound 0.765 -> 0.957);
    # measured 15.6 GiB/chip of 16 (tight — revert to remat or microbatch=2
    # if fragmentation bites on silicon).
    ("mistral-large-123b", "train_4k", None): {"remat": False},
    # internvl2-1b (d=896): hillclimb cells — 0.0014 -> 0.269 (train),
    # 0.0002 -> 0.0153 (prefill); see EXPERIMENTS.md §Perf.
    ("internvl2-1b", "train_4k", "single"): {"sharding_overrides": _PURE_DP_TRAIN},
    ("internvl2-1b", "train_4k", "multi"): {"sharding_overrides": _PURE_DP_TRAIN_MULTI},
    ("internvl2-1b", "prefill_32k", None): {"sharding_overrides": _SEQ_PARALLEL},
    # whisper-tiny (d=384): same degenerate-TP pathology as internvl.
    ("whisper-tiny", "train_4k", "single"): {"sharding_overrides": _PURE_DP_TRAIN},
    ("whisper-tiny", "train_4k", "multi"): {"sharding_overrides": _PURE_DP_TRAIN_MULTI},
    ("whisper-tiny", "prefill_32k", None): {"sharding_overrides": _SEQ_PARALLEL},
}


def tuned_overrides(arch: str, shape: str, mesh: str) -> dict:
    for key in ((arch, shape, mesh), (arch, shape, None), (arch, None, mesh),
                (arch, None, None)):
        if key in TUNED:
            return dict(TUNED[key])
    return {}
