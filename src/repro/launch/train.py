"""Distributed training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
        --smoke --steps 50 --mesh 1x1          # this container
    python -m repro.launch.train --arch mistral-large-123b \
        --mesh 16x16 --tuned                    # a real pod

Builds the mesh, shards params/optimizer from the logical rules, wires the
deterministic host-sharded data pipeline, and drives the jitted train step
with async checkpointing + restart.  The same entry point runs on 1 CPU
device or a 256-chip pod — only ``--mesh`` changes.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from ..ckpt import CheckpointManager
from ..configs import get_config, smoke_config
from ..data import DataConfig, SyntheticCorpus
from ..models import get_model
from ..optim.adamw import AdamWConfig, adamw_init
from ..optim.schedules import linear_warmup_cosine
from ..parallel.logical import split_logical
from ..parallel.sharding import (activation_rules, reset_activation_rules,
                                 rules_for_mesh)
from ..train.step import make_train_step
from .mesh import make_host_mesh


def parse_mesh(s: str):
    dims = tuple(int(x) for x in s.split("x"))
    axes = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
    return dims, axes


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU containers)")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--tuned", action="store_true")
    ap.add_argument("--ckpt-dir", default="artifacts/train")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--host-id", type=int, default=0)
    ap.add_argument("--n-hosts", type=int, default=1)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.tuned:
        cfg = cfg.replace(act_shard=True)
    api = get_model(cfg)

    dims, axes = parse_mesh(args.mesh)
    mesh = make_host_mesh(dims, axes)
    rules = rules_for_mesh(mesh, cfg.sharding_overrides)
    print(f"mesh {mesh.shape} | arch {cfg.name} "
          f"(~{cfg.param_count() / 1e6:.1f}M params, "
          f"DCIM INT{cfg.dcim_a_bits}xINT{cfg.dcim_w_bits})")

    tok = activation_rules(rules if cfg.act_shard else None)
    try:
        params_l = api.init_params(jax.random.PRNGKey(0))
        params, specs = split_logical(params_l, rules)
        shardings = jax.tree.map(lambda sp: NamedSharding(mesh, sp), specs)
        params = jax.device_put(params, shardings)
        opt = adamw_init(params)

        corpus = SyntheticCorpus(DataConfig(
            vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
            frontend_tokens=cfg.frontend.n_tokens if cfg.frontend else 0,
            frontend_dim=cfg.frontend.d_frontend if cfg.frontend else 0))
        lr = linear_warmup_cosine(args.lr, warmup=min(20, args.steps // 5),
                                  total_steps=args.steps)
        step_fn = jax.jit(make_train_step(api, lr, AdamWConfig(),
                                          microbatches=args.microbatches),
                          donate_argnums=(0, 1))
        mgr = CheckpointManager(args.ckpt_dir, keep=2, host_id=args.host_id)

        start = 0
        if mgr.latest_step() is not None:
            (params, opt), start = mgr.restore((params, opt))
            print(f"resumed from step {start}")

        t0 = time.time()
        with mesh:
            for step in range(start, args.steps):
                lo = args.host_id * (args.batch // args.n_hosts)
                hi = lo + args.batch // args.n_hosts
                raw = corpus.batch(step, lo, hi)
                batch = {k: jnp.asarray(v) for k, v in raw.items()}
                params, opt, m = step_fn(params, opt, batch)
                if step % 10 == 0 or step == args.steps - 1:
                    print(f"step {step:5d} loss={float(m['loss']):.4f} "
                          f"gnorm={float(m['grad_norm']):.3f} "
                          f"[{time.time() - t0:.1f}s]", flush=True)
                if (step + 1) % args.save_every == 0:
                    mgr.async_save(step + 1, (params, opt))
        mgr.wait()
        print(f"trained {args.steps - start} steps in {time.time() - t0:.1f}s")
    finally:
        reset_activation_rules(tok)


if __name__ == "__main__":
    main()
