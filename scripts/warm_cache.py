"""Pre-fill the frontier cache tiers ahead of a deployment.

Synthesizes the ``scenario_specs()`` × preference-grid product (every
scenario spec at every requested grid resolution, optionally plus the
exhaustive sweep that leaves per-axis slice records behind) and publishes
the frontiers into the given store — so the fleet's first ``launch.serve
--dcim-select`` is warm on every host, with zero engine executions:

    PYTHONPATH=src python scripts/warm_cache.py \\
        --registry /mnt/shared/syndcim-registry --resolutions 3,4,5 --sweep

Point ``--registry`` at shared storage to warm a whole fleet, or ``--store``
at a local directory to warm one host (both may be given).
``--autotune-kernels`` additionally pre-fills the kernel tile-autotune
artifacts (``repro.kernels.autotune``) into the registry, so serving hosts
launching with ``tile_config="auto"`` never pay a tuning sweep.  Re-running is
cheap and idempotent: already-published addresses are cache hits and are
skipped (content addressing), so a cron'd warm-up converges to a no-op.
Claim files coordinate concurrent warmers — two hosts warming the same
registry split the misses instead of duplicating them.
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

from repro.core.multispec import scenario_specs  # noqa: E402
from repro.service import (ArtifactRegistry, FrontierCache,  # noqa: E402
                           SynthesisRequest, SynthesisService)


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--registry", default=None, metavar="PATH",
                    help="fleet-shared artifact-registry root on shared "
                         "storage (what launch.serve --dcim-registry reads)")
    ap.add_argument("--store", default=None, metavar="PATH",
                    help="local frontier-store directory (what launch.serve "
                         "--dcim-cache reads)")
    ap.add_argument("--resolutions", default="4", metavar="R1,R2,...",
                    help="preference-grid resolutions to warm (default: 4, "
                         "the serving default)")
    ap.add_argument("--scenarios", default=None, metavar="NAME,...",
                    help="scenario subset (default: all of "
                         "scenario_specs())")
    ap.add_argument("--sweep", action="store_true",
                    help="also warm the exhaustive design-space sweep per "
                         "scenario, leaving per-axis slice records so the "
                         "fleet's next scoped recalibration re-synthesizes "
                         "incrementally")
    ap.add_argument("--mode", default="auto",
                    help="execution mode for the fused miss passes "
                         "(default: auto)")
    ap.add_argument("--autotune-kernels", default=None, metavar="SHAPES",
                    help="also pre-fill kernel tile-autotune artifacts into "
                         "--registry: comma-separated kernel:DxDx... entries "
                         "(e.g. dcim_mac:512x512x512,ssm_scan:4096x256), or "
                         "'default' for a stock serving sweep")
    ap.add_argument("--autotune-iters", type=int, default=3,
                    help="timing repetitions per tile candidate")
    args = ap.parse_args()

    if args.registry is None and args.store is None:
        ap.error("nothing to warm: pass --registry and/or --store")
    if args.autotune_kernels and args.registry is None:
        ap.error("--autotune-kernels persists through the shared registry; "
                 "pass --registry")
    resolutions = [int(r) for r in args.resolutions.split(",") if r.strip()]

    specs = scenario_specs()
    if args.scenarios is not None:
        wanted = [s.strip() for s in args.scenarios.split(",") if s.strip()]
        unknown = sorted(set(wanted) - set(specs))
        if unknown:
            ap.error(f"unknown scenarios {unknown}; have {sorted(specs)}")
        specs = {k: specs[k] for k in wanted}

    registry = (None if args.registry is None
                else ArtifactRegistry(args.registry))
    service = SynthesisService(
        mode=args.mode,
        cache=FrontierCache(store_dir=args.store, registry=registry))

    requests = [SynthesisRequest(spec=spec, resolution=r, tag=name)
                for name, spec in specs.items() for r in resolutions]
    if args.sweep:
        requests += [SynthesisRequest(spec=spec, kind="sweep", tag=name)
                     for name, spec in specs.items()]

    t0 = time.time()
    responses = service.serve(requests)
    elapsed = time.time() - t0

    filled = sum(1 for r in responses if r.served_from == "engine")
    warm = len(responses) - filled
    print(f"warm_cache: {len(responses)} addresses "
          f"({len(specs)} scenarios x {len(resolutions)} resolutions"
          + (" + sweeps" if args.sweep else "") + ") in {:.1f}s — "
          .format(elapsed)
          + f"{filled} synthesized, {warm} already warm")
    for section, counters in service.telemetry().items():
        line = " ".join(f"{k}={v}" for k, v in counters.items())
        print(f"warm_cache: {section}: {line}")

    if args.autotune_kernels:
        from repro.kernels import autotune as kernel_autotune
        if args.autotune_kernels == "default":
            targets = [("dcim_mac", (128, 512, 512)),
                       ("dcim_mac", (512, 512, 512)),
                       ("ssm_scan", (1024, 256)),
                       ("ssm_scan", (4096, 256)),
                       ("csa_tree", (256, 512)),
                       ("csa_tree", (1024, 512))]
        else:
            targets = []
            for entry in args.autotune_kernels.split(","):
                kernel, _, dims = entry.strip().partition(":")
                targets.append((kernel, tuple(int(d)
                                              for d in dims.split("x"))))
        t0 = time.time()
        for kernel, shape in targets:
            res = kernel_autotune.autotune(kernel, shape,
                                           iters=args.autotune_iters,
                                           registry=registry)
            print(f"warm_cache: autotune {kernel} "
                  f"{'x'.join(map(str, shape))} -> {res.winner.as_dict()} "
                  f"({res.time_us:.0f}us, "
                  f"nondefault={res.picked_nondefault})")
        print(f"warm_cache: {len(targets)} tile artifacts in "
              f"{time.time() - t0:.1f}s — serving hosts resolve them via "
              f"tile_config='auto'")


if __name__ == "__main__":
    main()
