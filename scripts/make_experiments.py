"""Assemble EXPERIMENTS.md from the dry-run/optimized artifacts + the static
reproduction and perf-log sections.  Rerun after any sweep:

    PYTHONPATH=src python scripts/make_experiments.py
"""

import json
import sys
from pathlib import Path

sys.path.insert(0, "src")

from repro.roofline.report import (dryrun_table, load_records, roofline_table,  # noqa: E402
                                   roofline_terms, skip_table)

ROOT = Path(__file__).resolve().parent.parent
BASE = ROOT / "artifacts/dryrun"
OPT = ROOT / "artifacts/optimized"

HEADER = """\
# EXPERIMENTS — SynDCIM-JAX

All numbers regenerate with the commands shown; artifacts live under
``artifacts/``.  Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
4x50 GB/s ICI links per chip, 16 GiB HBM (constants in
``src/repro/roofline/hw.py``).

## §Reproduction — the paper's own claims

``PYTHONPATH=src python -m benchmarks.run`` reproduces every table/figure:

| Claim (paper) | Paper value | Reproduced | Benchmark |
|---|---|---|---|
| fmax @1.2 V (Fig. 9) | 1.1 GHz | 1.100 GHz (calibration anchor) | fig9 |
| fmax @0.7 V (Fig. 9) | 300 MHz | 306 MHz — *predicted* by the alpha-power fit, not a knob | fig9 |
| Peak TOPS (1b-1b, 4 Kb) | 9.0 | 9.01 | fig9/table2 |
| TOPS/W @0.7 V (Table II) | 1921 | 1921 (anchor, leakage-corrected) | table2 |
| TOPS/mm² (Table II) | 80.5 | 80.5 | table2 |
| Macro area (Fig. 10) | 0.112 mm² | 0.112 mm² (anchor) | table2 |
| FP8 vs INT4 power (Fig. 7) | ≈ +10% | +9.3% @64×64 | fig7 |
| BF16 vs INT8 power (Fig. 7) | ≈ +20% | +22.2% @64×64 | fig7 |
| TOPS/W rises with array size (Fig. 7) | monotone 32²→256² | 2136→2396 TOPS/W (INT4, 0.7 V) | fig7 |
| Pareto frontier (Fig. 8) | multiple corners | 5 designs: 828 MHz/1404 TOPS/W ↔ 1084 MHz/1277 TOPS/W, all meet 800 MHz@0.9 V | fig8 |
| Feature matrix (Table I) | 4 checks | all four *executed*, not asserted | table1 |
| Alg. 1 techniques | tt1–tt5, ft1–ft3 | exercised + audit-logged (see quickstart) | fig8/csa |
| Gate-level verification | DRC/LVS/post-sim | synthesized CSA netlists *executed*: Σ exact on random tensors | csa |

Three calibration anchors (1.1 GHz@1.2 V, 0.112 mm², 1921 TOPS/W@0.7 V) solve
the three free technology units (tau, eps, APR overhead); everything else —
the 0.7 V frequency, the FP overheads, the dimension scaling, the whole
Pareto frontier — is *predicted* by the subcircuit models (see
``tests/test_core_compiler.py::TestSiliconAnchors``).

## §Dry-run

Every (architecture × applicable shape) cell lowered **and compiled** with
``jax.jit(...).lower().compile()`` on both production meshes
(single-pod 16×16 = 256 chips; multi-pod 2×16×16 = 512 chips), from
ShapeDtypeStructs — no allocation:

    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Notes on the recorded numbers:
  * ``memory_analysis`` on the forced-host platform aggregates across
    partitions; per-device figures divide by the device count (verified:
    whisper train temp/256 equals the per-device f32 logits+CE buffer).
  * FLOPs/bytes come from the trip-count-aware HLO cost walker
    (``repro/roofline/hlo_parse.py``): XLA's ``cost_analysis()`` counts scan
    bodies once (verified 8× undercount on an 8-step scan), so the walker
    re-derives costs from the optimized HLO text, multiplying while-bodies by
    XLA's own ``known_trip_count``.
  * bytes model: TPU-fusion projection — only dot/conv/reduce/gather/
    collective/update ops carry HBM traffic; in-place cache updates charge
    zero (aliased; reads are charged at the attention dots); XLA:CPU's
    bf16→f32 dot upcasts are projected back to bf16 sizes
    (``bf16_normalize=True``; raw numbers retained in ``cost_raw``).
"""

PERF = """\
## §Perf — hillclimb log (hypothesis → change → measure → validate)

Cells chosen from the baseline table: **internvl2-1b/prefill_32k** (worst
roofline fraction, 0.0002), **internvl2-1b/train_4k** (most collective-bound:
t_coll 58 s > t_mem 54 s), **mistral-large-123b/train_4k** (most
representative of the paper's technique: 123 B-parameter INT8-QAT training —
the paper's cloud-acceleration scenario — and the largest MODEL_FLOPS).

**The paper-faithful baseline is the full `artifacts/dryrun` table above.**
Optimized results live in `artifacts/optimized`; both are kept.

### Iteration 1 — activation sharding constraints (all three cells)

* **Hypothesis** (napkin): the HLO shows 2280 all-reduces of ~1.6 GiB
  (3.6 TiB/chip/step on llama train) — GSPMD resolves the d_in-'data'-sharded
  weight contraction by partial-sum + all-reduce of full f32 activations
  instead of all-gathering the (100× smaller) FSDP weight shards.
  Constraining every linear's output to (batch→data, features→model-if-TP)
  should force the weight-gather strategy: collective term ↓10–100×, memory
  ↓2–5×.
* **Change**: ``constrain_act`` after every DCIM linear / embedding / logits
  (``cfg.act_shard``; ``repro/parallel/sharding.py``).
* **Measured** (single-pod, t in ms: compute/memory/collective, mfu = roofline-MFU bound):

| cell | baseline | iteration 1 | verdict |
|---|---|---|---|
| mistral train_4k | 33992/170218/63028, mfu 0.090 | 19987/28384/21984, mfu 0.539 | **CONFIRMED** (6.0×) |
| internvl train_4k | 448/54278/58008, mfu 0.0014 | 100/3759/3897, mfu 0.020 | **CONFIRMED** (14×) |
| internvl prefill_32k | 569/90583/105837, mfu 0.0002 | 55/5745/6654, mfu 0.0039 | **CONFIRMED** (19×) |

### Iteration 2a — bf16-normalized measurement (correction, not a code change)

* **Hypothesis**: XLA:CPU upcasts bf16 dots to f32 (convert→f32-dot), so the
  walker charges 2× the TPU-native bytes for dot operands and the TP
  all-reduces that consume them.
* **Change**: resolve dot operands through converts; halve f32 collective
  tensors (``bf16_normalize``).  Applied to baseline AND optimized tables.
* **Measured**: mistral train mem 28.4 s→19.1 s, coll 22.0→11.0 s → mfu
  0.765, now *compute*-bound.  **CONFIRMED** (the residual f32 terms — CE,
  Adam moments — are <1% of traffic).

### Iteration 2b — layout: pure-DP for width-starved archs (internvl train)

* **Hypothesis**: d_model=896/16-way TP = 56 features/chip and 14 heads over
  16 shards pad to ~1/chip: per-layer attention emits padded-head
  all-reduces (~84/layer).  A 0.9 B model doesn't need TP at all at this
  scale: batch 256 over all 256 chips (features local, weights still
  FSDP-sharded) removes every TP collective at the cost of per-use weight
  gathers (~40 MB/layer — trivial).
* **Change**: ``sharding_overrides={"batch": ("data","model"), "act_heads":
  None, "act_ff": None}`` (tuned.py).
* **Measured**: 100/3759/3897 → 98/292/**22** ms, mfu 0.020→**0.269**,
  useful-flops 0.80.  **CONFIRMED** (13×; 190× vs baseline).

### Iteration 2b' — sequence parallelism (internvl prefill; batch 32 < 256)

* **Hypothesis**: batch can't cover the mesh (32 rows); shard the 32 k
  sequence over 'model' instead, keeping attention exact via the causal
  q-block loop.
* **Measured**: mfu 0.0039→0.0153, coll 6.7 s→1.7 s.  **CONFIRMED** (2×),
  but all-gathers remain (316 GiB: the q-block loop re-gathers K/V per
  block).
* **Iteration 3** — gather K/V once per layer before the q-loop
  (``attn_kv_seq`` constraint): **REFUTED** — identical numbers; the gathers
  are q-slice resharding, which the constraint can't remove.
* **Iteration 4** — heads-local without seq sharding: collective ↓ to 57 ms
  but attention compute replicates 16× over 'model' (useful 0.51→0.06), mfu
  0.0095 < 0.0153.  **REFUTED**.  Two consecutive <5% iterations → stop;
  remaining gap is structural (MODEL_FLOPS=2·N·D ignores the 32 k-seq
  attention FLOPs that dominate prefill for a 0.9 B model — useful-flops
  counts them at 0.51).

### Iteration 3' — remat off (mistral train)

* **Hypothesis**: compute term includes the remat re-forward (8/6 of model
  FLOPs); 123 B × bf16 FSDP over 256 chips leaves HBM headroom, so full
  activation residency may fit: compute −25%, memory reads −20%.
* **Measured**: 19987/19113/10992 → 15977/14693/10036 ms, mfu 0.765→**0.957**,
  HBM 15.6/16 GiB.  **CONFIRMED** — with the caveat that 97% HBM occupancy is
  fragile; production would use ``microbatches=2`` or selective remat as the
  fallback (knob exists: ``--microbatches``).

### Final per-cell results (baseline → optimized, single-pod)

Quoted under the FINAL cost model (bf16-normalized) applied to both sides —
the iteration log above quotes the values as measured at each point in time
(iterations 1–2a predate the normalization, so their raw baselines read
lower):

| cell | mfu bound before | after | total gain |
|---|---|---|---|
| mistral-large-123b train_4k | 0.132 | **0.957** | 7.3× |
| internvl2-1b train_4k | 0.0023 | **0.269** | 116× |
| internvl2-1b prefill_32k | 0.0005 | **0.0153** | 31× |

Stopping rule satisfied: the last iterations on each cell were either <5%
(prefill it.3) or explicitly refuted (prefill it.4); mistral is at 0.96 of
its roofline bound, within noise of the model's ceiling.

### Beyond-paper optimizations carried into the framework defaults

1. ``act_shard`` activation constraints (iteration 1) — applied to every
   **train/prefill** cell in the optimized sweep below.  A first optimized
   sweep applied them to decode too and *regressed* decode cells 0.5–0.9×
   (cache-read-bound steps gain nothing from weight-gather layouts; the
   constraints on (B,1,d) tensors only add resharding) — the tuned policy
   now arms them by workload kind.  Hypothesis→measure→refine, recorded.
2. Per-cell tuned layouts (``repro/launch/tuned.py``): pure-DP for
   width-starved train cells (internvl, whisper), sequence-parallel prefill
   for the same archs, remat-off for mistral train.
3. int8 error-feedback gradient compression across the 'pod' axis
   (``repro/optim/compression.py``, validated in tests/test_distributed.py)
   — 8× fewer DCN bytes for multi-pod gradient sync, with a global-scale
   agreement round (per-replica scales measured 20× worse error).
"""


def main():
    base = load_records(BASE)
    out = [HEADER]
    n_ok = sum(1 for r in base.values() if r.get("ok"))
    out.append(f"### Matrix ({n_ok}/{len(base)} cells compiled, 0 failures)\n")
    out.append(dryrun_table(base))
    out.append("\n### Skipped cells (per assignment rules)\n")
    out.append(skip_table())
    out.append("""
## §Roofline — baseline (paper-faithful configuration)

Terms per chip per step: compute = HLO_FLOPs/(197e12), memory =
HLO_bytes/(819e9), collective = ICI_bytes/(4×50e9).  ``useful/HLO`` =
MODEL_FLOPS/(HLO FLOPs × chips) — remat, QAT fake-quant, attention and
padding waste show up here.  ``roofline-MFU bound`` = the MFU the step would
achieve if it ran exactly at the dominant roofline term.
""")
    out.append("### Single-pod (16×16 = 256 chips)\n")
    out.append(roofline_table(base, "single"))
    out.append("\n### Multi-pod (2×16×16 = 512 chips)\n")
    out.append(roofline_table(base, "multi"))
    out.append("\n" + PERF)

    if OPT.exists():
        opt = load_records(OPT)
        n_ok = sum(1 for r in opt.values() if r.get("ok"))
        out.append(f"""
## §Roofline — optimized (beyond-paper defaults: act_shard + tuned layouts)

``PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --tuned``
({n_ok}/{len(opt)} cells compiled)

### Single-pod (256 chips)
""")
        out.append(roofline_table(opt, "single"))
        out.append("\n### Multi-pod (512 chips)\n")
        out.append(roofline_table(opt, "multi"))
        # improvement summary
        rows = ["\n### Baseline → optimized (single-pod mfu bound)\n",
                "| cell | baseline | optimized | gain |", "|---|---|---|---|"]
        for key in sorted(base):
            arch, shape, mesh = key
            if mesh != "single" or key not in opt:
                continue
            if not (base[key].get("ok") and opt[key].get("ok")):
                continue
            b = roofline_terms(base[key])["mfu_bound"]
            o = roofline_terms(opt[key])["mfu_bound"]
            gain = o / b if b else float("inf")
            rows.append(f"| {arch} {shape} | {b:.4f} | {o:.4f} | {gain:.1f}× |")
        out.append("\n".join(rows))

    (ROOT / "EXPERIMENTS.md").write_text("\n".join(out) + "\n")
    print("wrote EXPERIMENTS.md",
          len((ROOT / 'EXPERIMENTS.md').read_text().splitlines()), "lines")


if __name__ == "__main__":
    main()
