"""Profile the DCIM-path Pallas kernels: DMA vs compute vs fused.

Times the copy-only / compute-only / fused skeletons of each kernel
(``repro.kernels.profile``) over a shape sweep, classifies each point
bandwidth- vs compute-bound, and reports the roofline fraction (how much of
the fused time the slower pipeline side accounts for — 1.0 means the cheap
side is fully hidden):

    PYTHONPATH=src python scripts/profile_kernels.py --kernel all
    PYTHONPATH=src python scripts/profile_kernels.py \\
        --kernel dcim_mac --shapes 512x512x512,1024x1024x1024 --iters 5
    PYTHONPATH=src python scripts/profile_kernels.py --json profiles.json

Off-TPU (this container) the kernels run in Pallas interpret mode:
absolute numbers are meaningless there, but the tool exercises the full
plumbing, which is what CI smoke-tests.  On a real TPU the same invocation
produces actionable splits, and the ``--json`` artifact (schema
``syndcim-kernel-profile/v1``) feeds
``repro.launch.serve --dcim-kernel-profile PATH``, which derates
``repro.roofline.dcim.dcim_serving_bound(kernel_fraction=...)`` with the
measured pipeline efficiency.
"""

import argparse
import json
import sys

sys.path.insert(0, "src")

from repro.kernels.profile import (fraction_from_profiles, profile_kernel,  # noqa: E402
                                   profiles_payload)
from repro.kernels.tiles import KERNELS, TileConfig  # noqa: E402

#: Default shape sweep per kernel (serving-ish sizes; trimmed in --smoke).
DEFAULT_SHAPES = {
    "dcim_mac": [(128, 512, 512), (512, 512, 512)],
    "ssm_scan": [(1024, 256), (4096, 256)],
    "csa_tree": [(256, 512), (1024, 512)],
}

SMOKE_SHAPES = {
    "dcim_mac": [(32, 128, 128)],
    "ssm_scan": [(128, 128)],
    "csa_tree": [(600, 256)],
}


def parse_shapes(text: str) -> list[tuple[int, ...]]:
    return [tuple(int(d) for d in s.split("x")) for s in text.split(",") if s]


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--kernel", default="all",
                    help=f"one of {', '.join(KERNELS)}, or 'all'")
    ap.add_argument("--shapes", default=None, metavar="MxKxN,...",
                    help="comma-separated 'x'-joined shapes (only with a "
                         "single --kernel); default: a per-kernel sweep")
    ap.add_argument("--iters", type=int, default=3,
                    help="timing repetitions per skeleton (min taken)")
    ap.add_argument("--depth", type=int, default=None,
                    help="override the DMA pipeline buffer depth")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes (CI: plumbing only)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also dump the profiles as a schema'd JSON "
                         "artifact consumable by repro.launch.serve "
                         "--dcim-kernel-profile")
    args = ap.parse_args()

    kernels = list(KERNELS) if args.kernel == "all" else [args.kernel]
    for k in kernels:
        if k not in KERNELS:
            ap.error(f"unknown kernel {k!r}; have {', '.join(KERNELS)}")
    if args.shapes and len(kernels) != 1:
        ap.error("--shapes needs a single --kernel")

    shape_table = SMOKE_SHAPES if args.smoke else DEFAULT_SHAPES
    tc = TileConfig(depth=args.depth) if args.depth else None

    profiles = []
    hdr = (f"{'kernel':9s} {'shape':>18s} {'copy_us':>10s} {'compute_us':>11s} "
           f"{'fused_us':>10s} {'bound':>9s} {'roofline':>8s}")
    print(hdr)
    print("-" * len(hdr))
    for kernel in kernels:
        shapes = (parse_shapes(args.shapes) if args.shapes
                  else shape_table[kernel])
        for shape in shapes:
            p = profile_kernel(kernel, shape, tile_config=tc,
                               iters=args.iters)
            profiles.append(p)
            mark = "" if p.compute_measured else "*"
            print(f"{p.kernel:9s} {'x'.join(map(str, p.shape)):>18s} "
                  f"{p.t_copy_us:10.1f} {p.t_compute_us:10.1f}{mark:1s} "
                  f"{p.t_fused_us:10.1f} {p.bound:>9s} "
                  f"{p.roofline_fraction:8.3f}")
    print("-" * len(hdr))
    print(f"aggregate roofline fraction (geomean): "
          f"{fraction_from_profiles(profiles):.3f}"
          f"   (* = compute derived as fused - copy)")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(profiles_payload(profiles), f, indent=2,
                      sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
