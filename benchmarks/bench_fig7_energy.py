"""Fig. 7: post-layout energy efficiency of generated macros across
precisions (INT4/8, FP8, BF16) and dimensions (32x32 .. 256x256).

Expected reproduction: TOPS/W rises with array size (amortized peripherals +
CSA efficiency); FP8 ~ +10% power vs INT4; BF16 ~ +20% vs INT8."""

from __future__ import annotations

import dataclasses

from repro.core import (calibrated_tech_for_reference, reference_chip_design,
                        reference_chip_spec, rollup)

from .common import timed

DIMS = (32, 64, 128, 256)
MODES = ("int_lo", "int_hi", "FP8", "BF16")
LABEL = {"int_lo": "INT4", "int_hi": "INT8", "FP8": "FP8", "BF16": "BF16"}


def run() -> list[tuple]:
    tech = calibrated_tech_for_reference()
    rows = []

    def one(dim):
        spec = dataclasses.replace(reference_chip_spec(), h=dim, w=dim,
                                   vdd=0.7, int_precisions=(4, 8),
                                   fp_precisions=("FP8", "BF16"))
        d = dataclasses.replace(reference_chip_design(), spec=spec)
        return rollup(d, tech)

    for dim in DIMS:
        ppa, us = timed(one, dim)
        for m in MODES:
            eff = ppa.tops_per_w_1b[m]
            rows.append((f"fig7/{dim}x{dim}/{LABEL[m]}", us,
                         f"tops_per_w={eff:.0f}"))
        # headline deltas at this dimension
        fp8 = ppa.e_cycle_fj["FP8"] / ppa.e_cycle_fj["int_lo"] - 1
        bf16 = ppa.e_cycle_fj["BF16"] / ppa.e_cycle_fj["int_hi"] - 1
        rows.append((f"fig7/{dim}x{dim}/overhead", us,
                     f"fp8_vs_int4=+{fp8 * 100:.1f}%;bf16_vs_int8=+{bf16 * 100:.1f}%"))
    return rows
