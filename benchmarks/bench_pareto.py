"""Lattice-scale Pareto extraction: device-sharded map-reduce vs host numpy.

The tracked row is ``pareto/extract_speedup``: frontier extraction used to
serialize on one host as chunked numpy even when the sweep itself ran sharded
over every device; ``repro.core.pareto.nondominated_mask_sharded`` runs the
same eps-band dominance predicate as a jitted two-phase map-reduce (per-shard
local prefilter, cross-shard refinement) and must stay **bit-identical** —
same mask, same survivor order — while the wall-clock drops.  CI runs this
under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the sharded
row exercises a real multi-device placement."""

from __future__ import annotations

import numpy as np

import jax

from repro.core.engine import resolve_sharded_mode
from repro.core.pareto import (PARETO_EPS, nondominated_mask,
                               nondominated_mask_sharded)

from .common import timed

N_POINTS = 100_000     # lattice scale: ~3x the full 5-axis macro lattice
N_OBJECTIVES = 3       # (energy/cycle, area, period) — the searcher's tuple
SEED = 0


def _points() -> np.ndarray:
    rng = np.random.default_rng(SEED)
    objs = rng.uniform(0.1, 10.0, size=(N_POINTS, N_OBJECTIVES))
    # salt in the adversarial cases: exact duplicate + eps-near tie
    objs[N_POINTS // 2] = objs[0]
    objs[N_POINTS // 3] = objs[1] + PARETO_EPS / 4
    return objs


def run() -> list[tuple]:
    objs = _points()
    mode = resolve_sharded_mode("auto")
    n_dev = len(jax.devices())

    host_mask, us_host = timed(lambda: nondominated_mask(objs), iters=1)
    shard_mask, us_shard = timed(
        lambda: nondominated_mask_sharded(objs, mode=mode), iters=1)

    identical = (np.array_equal(host_mask, shard_mask)
                 and np.array_equal(np.flatnonzero(host_mask),
                                    np.flatnonzero(shard_mask)))
    survivors = int(host_mask.sum())

    return [
        (f"pareto/extract_host/{N_POINTS}pts", us_host,
         f"survivors={survivors}"),
        (f"pareto/extract_sharded/{N_POINTS}pts", us_shard,
         f"devices={n_dev};mode={mode}"),
        ("pareto/extract_speedup", us_shard,
         f"speedup={us_host / us_shard:.2f}x;identical={identical};"
         f"devices={n_dev};mode={mode};points={N_POINTS}"),
    ]
