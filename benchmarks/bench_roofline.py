"""Roofline table from the dry-run artifacts (artifacts/dryrun/*.json):
three terms per (arch x shape x mesh) + dominant bottleneck + MODEL_FLOPS
ratio.  Run the dry-run first; this bench only reads its outputs."""

from __future__ import annotations

import json
from pathlib import Path

from repro.configs import SHAPES, get_config
from repro.roofline import hw

ARTIFACTS = Path("artifacts/dryrun")


def model_flops_per_step(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch        # decode: one token per row


def roofline_row(rec: dict) -> dict:
    chips = rec["devices"]
    flops_dev = rec["cost"]["flops_per_device"]
    bytes_dev = rec["cost"]["bytes_per_device"]
    coll_dev = rec["cost"]["coll_bytes_per_device"]
    t_c = flops_dev / hw.PEAK_BF16_FLOPS
    t_m = bytes_dev / hw.HBM_BW
    t_x = hw.collective_time_s(coll_dev)
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    mf = model_flops_per_step(rec["arch"], rec["shape"])
    useful = mf / (flops_dev * chips) if flops_dev else 0.0
    bound = max(t_c, t_m, t_x)
    mfu_bound = (mf / chips / hw.PEAK_BF16_FLOPS) / bound if bound else 0.0
    return {"arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
            "bottleneck": dom, "model_flops": mf,
            "useful_flops_frac": useful, "roofline_mfu_bound": mfu_bound}


def run() -> list[tuple]:
    rows = []
    sets = [("baseline", ARTIFACTS), ("optimized", Path("artifacts/optimized"))]
    if not any(d.exists() for _, d in sets):
        return [("roofline/missing", 0.0,
                 "run `python -m repro.launch.dryrun --all --mesh both` first")]
    for label, artdir in sets:
        if not artdir.exists():
            continue
        for p in sorted(artdir.glob("*.json")):
            rec = json.loads(p.read_text())
            if not rec.get("ok"):
                rows.append((f"roofline/{label}/{p.stem}", 0.0, "FAILED"))
                continue
            r = roofline_row(rec)
            rows.append((f"roofline/{label}/{p.stem}", 0.0,
                         f"t_comp={r['t_compute_s']:.3e};"
                         f"t_mem={r['t_memory_s']:.3e};"
                         f"t_coll={r['t_collective_s']:.3e};"
                         f"dom={r['bottleneck']};"
                         f"useful_frac={r['useful_flops_frac']:.3f};"
                         f"mfu_bound={r['roofline_mfu_bound']:.3f}"))
    return rows
