"""Roofline table from the dry-run artifacts (artifacts/dryrun/*.json):
three terms per (arch x shape x mesh) + dominant bottleneck + MODEL_FLOPS
ratio.  Run the dry-run first; this bench only reads its outputs.

Also emits the DCIM serving roofline per deployed scenario: each workload's
selected macro (multi-spec frontier + preference-aware selection) fed through
``repro.roofline.dcim`` — roofline-bounded tokens/s, not just macro
wallclock.  These rows need no dry-run artifacts."""

from __future__ import annotations

import json
from pathlib import Path

from repro.configs import SHAPES, get_config
from repro.core import calibrated_tech_for_reference
from repro.core.dse import gemm_inventory
from repro.roofline import hw
from repro.serve.select import select_macros

from .common import timed

ARTIFACTS = Path("artifacts/dryrun")

DCIM_ARCHS = ("qwen3-4b", "internvl2-1b")
DCIM_RESOLUTION = 3
#: One preference posture per serving scenario: latency-first and energy-lean.
DCIM_PREFS = {"wallclock": (1.0, 0.0, 0.0), "energy": (0.2, 0.6, 0.2)}


def model_flops_per_step(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch        # decode: one token per row


def roofline_row(rec: dict) -> dict:
    chips = rec["devices"]
    flops_dev = rec["cost"]["flops_per_device"]
    bytes_dev = rec["cost"]["bytes_per_device"]
    coll_dev = rec["cost"]["coll_bytes_per_device"]
    t_c = flops_dev / hw.PEAK_BF16_FLOPS
    t_m = bytes_dev / hw.HBM_BW
    t_x = hw.collective_time_s(coll_dev)
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    mf = model_flops_per_step(rec["arch"], rec["shape"])
    useful = mf / (flops_dev * chips) if flops_dev else 0.0
    bound = max(t_c, t_m, t_x)
    mfu_bound = (mf / chips / hw.PEAK_BF16_FLOPS) / bound if bound else 0.0
    return {"arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
            "bottleneck": dom, "model_flops": mf,
            "useful_flops_frac": useful, "roofline_mfu_bound": mfu_bound}


def dcim_serving_rows() -> list[tuple]:
    """Serving roofline of each deployed workload on its selected macro, for
    both preference postures (the compiler->serving feedback loop).  The
    multi-spec synthesis + co-design matrix is built once; each posture only
    re-scalarizes the shared pooled frontier."""
    from repro.roofline.dcim import dcim_serving_bound
    from repro.serve.select import preferred_macro

    tech = calibrated_tech_for_reference()
    workloads = {a: gemm_inventory(get_config(a)) for a in DCIM_ARCHS}
    # Fresh service: keep the reported time the COLD synthesis+selection
    # cost, immune to whatever the process-wide service cached earlier in
    # this benchmark run.
    from repro.service import SynthesisService
    sel, us = timed(lambda: select_macros(
        workloads, tech=tech, resolution=DCIM_RESOLUTION,
        service=SynthesisService(tech=tech, resolution=DCIM_RESOLUTION)),
        warmup=0, iters=1)
    rows = []
    for pname, pref in sorted(DCIM_PREFS.items()):
        for w in sel.workloads:
            wi = sel.codesign.workloads.index(w)
            di = preferred_macro(sel.codesign, w, pref)
            est = dcim_serving_bound(
                workloads[w], float(sel.codesign.wallclock_s[wi, di]),
                workload=w, macro=sel.pool_labels[di])
            rows.append((f"roofline/dcim/{pname}/{w}", us,
                         f"macro={sel.pool_labels[di]};"
                         f"tok_s={est.tokens_per_s:.1f};"
                         f"bound={est.bottleneck};"
                         f"t_macro_ms={est.t_macro_s * 1e3:.4f};"
                         f"t_hbm_ms={est.t_hbm_s * 1e3:.4f}"))
    return rows


def run() -> list[tuple]:
    rows = dcim_serving_rows()
    sets = [("baseline", ARTIFACTS), ("optimized", Path("artifacts/optimized"))]
    if not any(d.exists() for _, d in sets):
        return rows + [
            ("roofline/missing", 0.0,
             "run `python -m repro.launch.dryrun --all --mesh both` first")]
    for label, artdir in sets:
        if not artdir.exists():
            continue
        for p in sorted(artdir.glob("*.json")):
            rec = json.loads(p.read_text())
            if not rec.get("ok"):
                rows.append((f"roofline/{label}/{p.stem}", 0.0, "FAILED"))
                continue
            r = roofline_row(rec)
            rows.append((f"roofline/{label}/{p.stem}", 0.0,
                         f"t_comp={r['t_compute_s']:.3e};"
                         f"t_mem={r['t_memory_s']:.3e};"
                         f"t_coll={r['t_collective_s']:.3e};"
                         f"dom={r['bottleneck']};"
                         f"useful_frac={r['useful_flops_frac']:.3f};"
                         f"mfu_bound={r['roofline_mfu_bound']:.3f}"))
    return rows
