"""Multi-spec vmapped co-synthesis: N scenario specs synthesized in one fused
pass (repro.core.multispec.mso_search_many) vs the per-spec batched loop.

The tracked row is ``multispec/vmap_speedup``: the fused pass must beat
looping ``mso_search(backend="batched")`` over the same specs while returning
bit-identical frontiers.  Also times the serving-time macro-selection step
(multi-spec frontier -> cross-workload co-design -> per-workload macro)."""

from __future__ import annotations

import dataclasses

from repro.configs import get_config
from repro.core import batched as B
from repro.core import calibrated_tech_for_reference
from repro.core.dse import gemm_inventory
from repro.core.multispec import mso_search_many, scenario_specs
from repro.core.shardspec import spec_variants
from repro.serve.select import select_macros

from .common import frontiers_identical, timed

GRID_RESOLUTION = 5
SELECT_ARCHS = ("qwen3-4b", "internvl2-1b", "granite-moe-1b-a400m")
SPEC_SEED = 0          # posture variants are seeded -> identical every run


def _spec_set() -> list:
    """The §I scenario specs plus seeded posture variants and one
    heterogeneous-geometry spec — a realistic multi-macro co-synthesis
    request, deterministic across runs."""
    scen = scenario_specs()
    specs = list(scen.values())
    specs += spec_variants(3, base=scen["vision"], seed=SPEC_SEED)
    specs.append(dataclasses.replace(scen["language"], h=128, w=128))
    return specs


def run() -> list[tuple]:
    tech = calibrated_tech_for_reference()
    specs = _spec_set()

    def per_spec_loop():
        # A fresh multi-spec request: the characterize-once cache holds no
        # evaluated lattices for these specs.
        B._evaluated.cache_clear()
        return [B.mso_search_batched(s, None, tech,
                                     resolution=GRID_RESOLUTION)
                for s in specs]

    def fused():
        return mso_search_many(specs, None, tech,
                               resolution=GRID_RESOLUTION)

    loop_res, us_loop = timed(per_spec_loop, iters=3)
    many_res, us_many = timed(fused, iters=3)

    identical = frontiers_identical(loop_res, many_res)
    frontier_pts = sum(len(r.frontier) for r in many_res)

    rows = [
        (f"multispec/search_loop/{len(specs)}specs", us_loop,
         f"frontier_pts={frontier_pts}"),
        (f"multispec/search_vmap/{len(specs)}specs", us_many,
         f"frontier_pts={frontier_pts}"),
        ("multispec/vmap_speedup", us_many,
         f"speedup={us_loop / us_many:.2f}x;identical={identical};"
         f"specs={len(specs)}"),
    ]

    # ---- serving-time macro selection over the multi-spec frontier ---------
    # A fresh SynthesisService per call keeps this row measuring COLD
    # selection (synthesis included): select_macros memoizes through the
    # process-wide service by default, which would turn the timed call into
    # a cache hit after the warmup.
    from repro.service import SynthesisService
    workloads = {a: gemm_inventory(get_config(a)) for a in SELECT_ARCHS}
    sel, us_sel = timed(
        lambda: select_macros(workloads, tech=tech,
                              resolution=GRID_RESOLUTION,
                              service=SynthesisService(
                                  tech=tech, resolution=GRID_RESOLUTION)),
        iters=1)
    s = sel.summary()
    rows.append((f"multispec/select/{len(workloads)}workloads", us_sel,
                 f"candidates={s['candidates']};"
                 f"codesign_frontier={s['codesign_frontier']}"))
    for w in sel.workloads:
        rows.append((f"multispec/select/{w}", us_sel,
                     f"macro={sel.label_for(w)}"))
    return rows
