"""Async serving front under a closed-loop Poisson arrival stream.

``bench_service`` measures batch-shaped dispatch (caller already holds a
wave); this benchmark measures the *serving* question: a client submits
single-spec requests at Poisson-distributed arrival times (seeded, so runs
are reproducible) against the :class:`repro.service.ServiceFrontend` —
bounded admission queue, priority classes, adaptive batching window, one
fused engine pass per drained batch — and we track what a load test tracks:

  ``service/p50_latency_ms``     median submit-to-served wall latency;
  ``service/p99_latency_ms``     tail latency (the first cold fused pass —
                                 jit-warm but cache-cold — dominates it);
  ``service/sustained_specs_s``  served requests per wall-clock second over
                                 the whole stream.

All three are asserted present in CI's bench.json.  Every row carries
``identical=`` — the async path must stay bit-identical to the blocking
``synthesize_many`` path over the same stream (same cache/coalesce/fused
tiers, scheduling only) — and the p99 row carries ``shedded=``, which must
be 0 here (the queue is sized for the stream; overload shedding is
exercised by the backpressure tests, not the latency benchmark).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import calibrated_tech_for_reference
from repro.service import (Priority, ServiceFrontend, SynthesisRequest,
                           SynthesisService)
from repro.core.shardspec import spec_variants

from .common import frontiers_identical

N_UNIQUE = 6           # distinct postures in the request pool
N_REQUESTS = 24        # closed-loop stream length
RATE_HZ = 60.0         # Poisson arrival rate
STREAM_SEED = 0
GRID_RESOLUTION = 3
WINDOW_S = 0.01        # base batching window (adapts to pass latency)
MAX_BATCH = 8
MAX_DEPTH = 64         # > N_REQUESTS: the latency bench must not shed


def _stream(uniques):
    rng = np.random.default_rng(STREAM_SEED)
    picks = rng.integers(0, len(uniques), N_REQUESTS)
    gaps = rng.exponential(1.0 / RATE_HZ, N_REQUESTS)
    # every 4th request is a BULK-class submission — the mixed-priority
    # shape real traffic has (selection vs sweep)
    prios = [Priority.BULK if i % 4 == 3 else Priority.INTERACTIVE
             for i in range(N_REQUESTS)]
    return [uniques[int(i)] for i in picks], gaps, prios


def run() -> list[tuple]:
    tech = calibrated_tech_for_reference()
    uniques = spec_variants(N_UNIQUE, seed=STREAM_SEED)
    stream, gaps, prios = _stream(uniques)

    # Blocking reference over the same stream (also warms the jit caches, so
    # the async run measures serving latency, not XLA compile time).
    ref_svc = SynthesisService(tech=tech, resolution=GRID_RESOLUTION)
    ref = [r.result for r in ref_svc.serve(
        [SynthesisRequest(spec=s) for s in stream])]

    # The closed-loop async run: a fresh service (cache-cold), Poisson
    # arrivals, latencies measured per request from the response stamps.
    svc = SynthesisService(tech=tech, resolution=GRID_RESOLUTION)
    front = ServiceFrontend(svc, window=WINDOW_S, max_batch=MAX_BATCH,
                            max_depth=MAX_DEPTH)
    t0 = time.monotonic()
    tickets = []
    for spec, gap, prio in zip(stream, gaps, prios):
        time.sleep(gap)
        tickets.append(front.submit(SynthesisRequest(
            spec=spec, priority=prio)))
    responses = [t.result(timeout=600) for t in tickets]
    elapsed_s = time.monotonic() - t0
    front.close()

    served = [r for r in responses if r.result is not None]
    shedded = len(responses) - len(served)
    lat_ms = np.array([r.latency_s for r in served]) * 1e3
    p50, p99 = np.percentile(lat_ms, 50), np.percentile(lat_ms, 99)
    specs_s = len(served) / elapsed_s
    identical = (shedded == 0
                 and frontiers_identical(ref, [r.result for r in served]))
    s, f = svc.stats, front.stats
    mix = (f"requests={N_REQUESTS};unique={N_UNIQUE};rate_hz={RATE_HZ};"
           f"batches={f.batches};max_batch={f.max_batch};"
           f"window_ms={front.effective_window() * 1e3:.1f}")

    return [
        ("service/p50_latency_ms", p50 * 1e3,
         f"p50_ms={p50:.2f};identical={identical};{mix}"),
        ("service/p99_latency_ms", p99 * 1e3,
         f"p99_ms={p99:.2f};shedded={shedded};depth_hwm={f.depth_hwm};"
         f"identical={identical}"),
        ("service/sustained_specs_s", elapsed_s * 1e6,
         f"specs_s={specs_s:.2f};identical={identical};"
         f"cache_hits={s.cache_hits};coalesced={s.coalesced};"
         f"misses={s.misses};fused_passes={s.fused_passes}"),
    ]
