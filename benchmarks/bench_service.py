"""Online synthesis service: coalesced+cached request serving vs naive
per-request synthesis on a closed-loop mixed hit/miss request stream.

The stream draws single-spec requests from a small posture pool (seeded, so
runs are reproducible) and submits them in waves, the way a serving front
sees traffic: the first wave is mostly cache misses, later waves mix warm
hits with stragglers.  The naive baseline synthesizes every request from
cold with its own engine pass; the service dedups against the content-
addressed frontier cache, coalesces in-batch duplicates, and fuses the
remaining misses into one engine pass per wave.

The tracked rows are ``service/coalesce_speedup`` (asserted present in CI's
bench.json, required >= 2x by the acceptance bar) and
``service/shared_hit_rate`` (the fleet drill: a second service instance
over one shared artifact registry must answer the whole stream from the
shared tier — hit_rate 1.0, zero fused passes); both carry ``identical=`` —
per-request results must stay bit-identical to the naive passes while the
dispatch collapses."""

from __future__ import annotations

import tempfile

import numpy as np

from repro.core import calibrated_tech_for_reference
from repro.core.multispec import mso_search_many
from repro.core.shardspec import spec_variants
from repro.service import (ArtifactRegistry, FrontierCache,
                           SynthesisRequest, SynthesisService)

from .common import frontiers_identical, timed

N_UNIQUE = 6           # distinct postures in the request pool
N_REQUESTS = 24        # total closed-loop stream length
WAVE = 8               # requests per coalescing window
STREAM_SEED = 0
GRID_RESOLUTION = 3


def _stream(uniques):
    rng = np.random.default_rng(STREAM_SEED)
    picks = rng.integers(0, len(uniques), N_REQUESTS)
    return [uniques[int(i)] for i in picks]


def run() -> list[tuple]:
    tech = calibrated_tech_for_reference()
    uniques = spec_variants(N_UNIQUE, seed=STREAM_SEED)
    stream = _stream(uniques)
    waves = [stream[i:i + WAVE] for i in range(0, len(stream), WAVE)]

    def naive():
        # One cold engine pass per request — no cache, no coalescing.
        return [mso_search_many([s], None, tech,
                                resolution=GRID_RESOLUTION)[0]
                for s in stream]

    def serviced():
        svc = SynthesisService(tech=tech, resolution=GRID_RESOLUTION)
        out = []
        for wave in waves:
            out.extend(r.result for r in svc.serve(
                [SynthesisRequest(spec=s) for s in wave]))
        return out, svc

    ref, us_naive = timed(naive, iters=1)
    (got, svc), us_svc = timed(serviced, iters=1)

    identical = frontiers_identical(ref, got)
    s = svc.stats

    # The fleet drill: host A fills a shared registry, host B (a separate
    # service instance with its own empty LRU) serves the same stream
    # entirely off the shared tier — zero engine passes.
    with tempfile.TemporaryDirectory() as reg_root:
        host_a = SynthesisService(
            tech=tech, resolution=GRID_RESOLUTION,
            cache=FrontierCache(registry=ArtifactRegistry(reg_root)))
        for wave in waves:
            host_a.serve([SynthesisRequest(spec=sp) for sp in wave])

        def shared_warm():
            host_b = SynthesisService(
                tech=tech, resolution=GRID_RESOLUTION,
                cache=FrontierCache(registry=ArtifactRegistry(reg_root)))
            out = []
            for wave in waves:
                out.extend(r.result for r in host_b.serve(
                    [SynthesisRequest(spec=sp) for sp in wave]))
            return out, host_b

        (warm, host_b), us_shared = timed(shared_warm, iters=1)
    shared_identical = frontiers_identical(ref, warm)
    cs = host_b.cache.stats
    hit_rate = (cs.hits + cs.shared_hits) / max(cs.gets, 1)

    return [
        (f"service/synthesize_naive/{N_REQUESTS}req", us_naive,
         f"requests={N_REQUESTS};unique={N_UNIQUE}"),
        (f"service/synthesize_service/{N_REQUESTS}req", us_svc,
         f"cache_hits={s.cache_hits};coalesced={s.coalesced};"
         f"misses={s.misses};fused_passes={s.fused_passes}"),
        ("service/coalesce_speedup", us_svc,
         f"speedup={us_naive / us_svc:.2f}x;identical={identical};"
         f"requests={N_REQUESTS};unique={N_UNIQUE};waves={len(waves)}"),
        ("service/shared_hit_rate", us_shared,
         f"hit_rate={hit_rate:.2f};shared_hits={cs.shared_hits};"
         f"fused_passes={host_b.stats.fused_passes};"
         f"fills={host_b.cache.registry.stats.fills};"
         f"identical={shared_identical};"
         f"speedup={us_naive / us_shared:.2f}x;requests={N_REQUESTS}"),
    ]
