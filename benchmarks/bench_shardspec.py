"""Device-sharded 100+-spec co-synthesis: ``mso_search_many_sharded`` vs the
unsharded vmapped pass on the same deterministic spec sweep.

The tracked row is ``shardspec/shard_speedup``: the sharded engine must keep
returning bit-identical per-spec frontiers (the differential oracle harness
pins this against the scalar path too) while the spec axis is partitioned
across every visible device.  On 1 host device the two paths coincide
(speedup ~1x); CI also runs this under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``-style fake-device
splits via the sharded test suite."""

from __future__ import annotations

import jax

from repro.core import calibrated_tech_for_reference
from repro.core.multispec import mso_search_many
from repro.core.shardspec import (mso_search_many_sharded, resolve_mode,
                                  spec_variants)

from .common import frontiers_identical, timed

N_SPECS = 104          # a real 100+-spec sweep request
SPEC_SEED = 0          # deterministic sweep across runs
GRID_RESOLUTION = 4


def run() -> list[tuple]:
    tech = calibrated_tech_for_reference()
    specs = spec_variants(N_SPECS, seed=SPEC_SEED)
    mode = resolve_mode("auto")
    n_dev = len(jax.devices())

    ref, us_ref = timed(lambda: mso_search_many(
        specs, None, tech, resolution=GRID_RESOLUTION), iters=2)
    got, us_shard = timed(lambda: mso_search_many_sharded(
        specs, None, tech, resolution=GRID_RESOLUTION), iters=2)

    identical = frontiers_identical(ref, got)
    frontier_pts = sum(len(r.frontier) for r in got)

    return [
        (f"shardspec/search_unsharded/{N_SPECS}specs", us_ref,
         f"frontier_pts={frontier_pts}"),
        (f"shardspec/search_sharded/{N_SPECS}specs", us_shard,
         f"devices={n_dev};mode={mode}"),
        ("shardspec/shard_speedup", us_shard,
         f"speedup={us_ref / us_shard:.2f}x;identical={identical};"
         f"devices={n_dev};mode={mode};specs={N_SPECS}"),
    ]
