"""Fig. 8: MSO-searched Pareto frontier for the paper's spec
(H=W=64, MCR=2, INT4/8 + FP4/8, 800 MHz MAC & weight update @ 0.9 V).

Runs the scalar reference hierarchy and the batched design-space engine on
the same preference grid: the frontier must be identical and the batched
sweep substantially faster (the engine evaluates the whole design lattice in
one fused pass and replays Alg. 1 as masked selection)."""

from __future__ import annotations

from repro.core import (SubcircuitLibrary, calibrated_tech_for_reference,
                        mso_search, pareto_experiment_spec)

from .common import timed

GRID_RESOLUTION = 5


def run() -> list[tuple]:
    tech = calibrated_tech_for_reference()
    scl = SubcircuitLibrary(tech).build()
    spec = pareto_experiment_spec()
    res_scalar, us_scalar = timed(
        lambda: mso_search(spec, scl, tech, resolution=GRID_RESOLUTION),
        iters=3)
    res, us = timed(
        lambda: mso_search(spec, scl, tech, resolution=GRID_RESOLUTION,
                           backend="batched"), iters=3)
    identical = (
        len(res.frontier) == len(res_scalar.frontier)
        and all(a.design.name() == b.design.name()
                and a.e_cycle_fj == b.e_cycle_fj
                and a.area_um2 == b.area_um2 and a.fmax_hz == b.fmax_hz
                for a, b in zip(res_scalar.frontier, res.frontier)))
    rows = [("fig8/search_scalar", us_scalar,
             f"explored={res_scalar.n_evaluated};"
             f"frontier={len(res_scalar.frontier)}"),
            ("fig8/search_batched", us,
             f"explored={res.n_evaluated};frontier={len(res.frontier)}"),
            ("fig8/batched_speedup", us,
             f"speedup={us_scalar / us:.2f}x;identical={identical}")]
    for p in res.frontier:
        s = p.summary()
        rows.append((f"fig8/point/{s['design']}", us,
                     f"fmax_mhz={s['fmax_mhz']};area_mm2={s['area_mm2']};"
                     f"tops_w={s['tops_w_int_lo']};tops_mm2={s['tops_mm2']};"
                     f"meets={s['meets_timing']}"))
    # frontier must span energy- and area/throughput-efficient corners
    effs = [p.tops_per_w_1b["int_lo"] for p in res.frontier]
    fm = [p.fmax_hz for p in res.frontier]
    rows.append(("fig8/span", us,
                 f"eff_ratio={max(effs) / min(effs):.2f};"
                 f"fmax_ratio={max(fm) / min(fm):.2f}"))
    return rows
