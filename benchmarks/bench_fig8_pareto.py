"""Fig. 8: MSO-searched Pareto frontier for the paper's spec
(H=W=64, MCR=2, INT4/8 + FP4/8, 800 MHz MAC & weight update @ 0.9 V)."""

from __future__ import annotations

from repro.core import (SubcircuitLibrary, calibrated_tech_for_reference,
                        mso_search, pareto_experiment_spec)

from .common import timed


def run() -> list[tuple]:
    tech = calibrated_tech_for_reference()
    scl = SubcircuitLibrary(tech).build()
    spec = pareto_experiment_spec()
    res, us = timed(lambda: mso_search(spec, scl, tech), iters=1)
    rows = [("fig8/search", us,
             f"explored={res.n_evaluated};frontier={len(res.frontier)}")]
    for p in res.frontier:
        s = p.summary()
        rows.append((f"fig8/point/{s['design']}", us,
                     f"fmax_mhz={s['fmax_mhz']};area_mm2={s['area_mm2']};"
                     f"tops_w={s['tops_w_int_lo']};tops_mm2={s['tops_mm2']};"
                     f"meets={s['meets_timing']}"))
    # frontier must span energy- and area/throughput-efficient corners
    effs = [p.tops_per_w_1b["int_lo"] for p in res.frontier]
    fm = [p.fmax_hz for p in res.frontier]
    rows.append(("fig8/span", us,
                 f"eff_ratio={max(effs) / min(effs):.2f};"
                 f"fmax_ratio={max(fm) / min(fm):.2f}"))
    return rows
