"""Benchmark harness utilities: every benchmark emits
``name,us_per_call,derived`` CSV rows (derived = the quantity the paper's
table/figure reports)."""

from __future__ import annotations

import time
from typing import Callable


def timed(fn: Callable, *args, warmup: int = 1, iters: int = 3, **kw):
    """Returns (result, us_per_call)."""
    for _ in range(warmup):
        result = fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(iters):
        result = fn(*args, **kw)
    us = (time.perf_counter() - t0) / iters * 1e6
    return result, us


def emit(rows: list[tuple]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
