"""Benchmark harness utilities: every benchmark emits
``name,us_per_call,derived`` CSV rows (derived = the quantity the paper's
table/figure reports)."""

from __future__ import annotations

import time
from typing import Callable


def timed(fn: Callable, *args, warmup: int = 1, iters: int = 3, **kw):
    """Returns (result, us_per_call)."""
    for _ in range(warmup):
        result = fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(iters):
        result = fn(*args, **kw)
    us = (time.perf_counter() - t0) / iters * 1e6
    return result, us


def emit(rows: list[tuple]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


def rows_to_dicts(module: str, rows: list[tuple]) -> list[dict]:
    """Machine-readable form of the CSV rows for the --json perf artifact.
    The ``derived`` field's ``k=v;k=v`` pairs are split out so trajectory
    tooling can track individual metrics across PRs."""
    out = []
    for name, us, derived in rows:
        metrics = {}
        for part in str(derived).split(";"):
            if "=" in part:
                k, _, v = part.partition("=")
                metrics[k] = v
        out.append({"module": module, "name": name,
                    "us_per_call": round(us, 1), "derived": derived,
                    "metrics": metrics})
    return out


def frontier_key(p):
    """Stable sort key for frontier MacroPPAs."""
    return (p.design.name(), p.area_um2, p.fmax_hz)


def frontiers_identical(results_a, results_b) -> bool:
    """Sorted-frontier equivalence over two SearchResult sequences:
    near-PARETO_EPS ties may legitimately reorder between paths/runs, never
    differ in membership or values — so benches compare membership and
    per-point values after a stable sort."""
    return all(
        len(a.frontier) == len(b.frontier)
        and all(x.design.name() == y.design.name()
                and x.e_cycle_fj == y.e_cycle_fj
                and x.area_um2 == y.area_um2 and x.fmax_hz == y.fmax_hz
                for x, y in zip(sorted(a.frontier, key=frontier_key),
                                sorted(b.frontier, key=frontier_key)))
        for a, b in zip(results_a, results_b))
