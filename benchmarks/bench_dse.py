"""System-level DSE (the paper's §I framing): map each assigned
architecture's GEMM inventory onto arrays of SynDCIM macros and report
accelerator throughput/energy — including the MCR/weight-update angle for
MoE (expert weights swap per batch)."""

from __future__ import annotations

import dataclasses

from repro.configs import get_config, list_archs
from repro.core import (GemmShape, accelerator_report,
                        calibrated_tech_for_reference, reference_chip_design,
                        reference_chip_ppa, rollup)

from .common import timed


def gemm_inventory(cfg, seq: int = 256) -> list[GemmShape]:
    """Per-token-batch GEMMs of one decoder layer x n_layers (weight-side
    inventory; attention score/value matmuls are activation-activation and
    stay outside the weight-stationary CIM mapping)."""
    d, hd = cfg.d_model, cfg.hd
    gs = [
        GemmShape("wq", seq, d, cfg.n_heads * hd, cfg.n_layers),
        GemmShape("wk", seq, d, cfg.n_kv_heads * hd, cfg.n_layers),
        GemmShape("wv", seq, d, cfg.n_kv_heads * hd, cfg.n_layers),
        GemmShape("wo", seq, cfg.n_heads * hd, d, cfg.n_layers),
    ]
    if cfg.family == "moe":
        e_active = cfg.moe.top_k
        gs += [GemmShape("moe_up", seq, d, 2 * cfg.moe.d_expert,
                         cfg.n_layers * e_active),
               GemmShape("moe_down", seq, cfg.moe.d_expert, d,
                         cfg.n_layers * e_active)]
    else:
        gs += [GemmShape("mlp_up", seq, d, 2 * cfg.d_ff, cfg.n_layers),
               GemmShape("mlp_down", seq, cfg.d_ff, d, cfg.n_layers)]
    return gs


def run() -> list[tuple]:
    ppa = reference_chip_ppa()
    tech = calibrated_tech_for_reference()
    rows = []
    for arch in list_archs():
        cfg = get_config(arch)
        gemms = gemm_inventory(cfg)
        rep, us = timed(lambda: accelerator_report(gemms, ppa, n_macros=256,
                                                   ib=8, wb=8), iters=1)
        s = rep.summary()
        rows.append((f"dse/{arch}/256macros", us,
                     f"eff_tops={s['effective_tops']};util={s['avg_util']};"
                     f"energy_uj={s['energy_uj']};area_mm2={s['area_mm2']}"))
    # MCR sensitivity on the MoE arch: higher MCR -> fewer weight reloads
    cfg = get_config("granite-moe-1b-a400m")
    gemms = gemm_inventory(cfg)
    for mcr in (1, 2, 4):
        spec = dataclasses.replace(reference_chip_design().spec, mcr=mcr)
        d = dataclasses.replace(reference_chip_design(), spec=spec)
        p = rollup(d, tech)
        rep, us = timed(lambda: accelerator_report(gemms, p, n_macros=64),
                        iters=1)
        reloads = sum(r.weight_reloads for r in rep.reports)
        rows.append((f"dse/moe_mcr{mcr}", us,
                     f"weight_reloads={reloads};"
                     f"cycles={rep.total_cycles}"))
    return rows
