"""System-level DSE (the paper's §I framing): map each assigned
architecture's GEMM inventory onto arrays of SynDCIM macros and report
accelerator throughput/energy — including the MCR/weight-update angle for
MoE (expert weights swap per batch), plus the batched cross-scenario
co-design sweep (every model-zoo workload x every candidate design point in
one fused pass, Fig. 8-style frontier across vision/language/MoE)."""

from __future__ import annotations

import dataclasses

from repro.configs import get_config, list_archs
from repro.core import (accelerator_report, calibrated_tech_for_reference,
                        cross_workload_codesign, design_space_sweep,
                        gemm_inventory, mso_search_batched,
                        pareto_experiment_spec, reference_chip_design,
                        reference_chip_ppa, rollup)

from .common import timed

N_MACROS = 256


def candidate_designs(tech, n_extra: int = 96) -> list:
    """Co-design candidate pool: the silicon reference, the MSO-explored
    designs, and a slice of the exhaustive-lattice frontier + neighborhood."""
    ppas = [reference_chip_ppa()]
    res = mso_search_batched(pareto_experiment_spec(), None, tech,
                             resolution=5)
    ppas += list(res.explored)
    sweep = design_space_sweep(pareto_experiment_spec(), tech)
    idx = list(sweep.frontier_indices())
    # pad with a deterministic stride through the valid feasible lattice
    import numpy as np
    feas = np.flatnonzero(sweep.lattice.valid & sweep.ppa.meets)
    stride = max(1, len(feas) // n_extra)
    idx += [int(i) for i in feas[::stride][:n_extra]]
    seen = {p.design.name() for p in ppas}
    for i in idx:
        p = sweep.materialize(i)
        if p.design.name() not in seen:
            seen.add(p.design.name())
            ppas.append(p)
    return ppas


def run() -> list[tuple]:
    ppa = reference_chip_ppa()
    tech = calibrated_tech_for_reference()
    rows = []
    for arch in list_archs():
        cfg = get_config(arch)
        gemms = gemm_inventory(cfg)
        rep, us = timed(lambda: accelerator_report(gemms, ppa,
                                                   n_macros=N_MACROS,
                                                   ib=8, wb=8), iters=1)
        s = rep.summary()
        rows.append((f"dse/{arch}/{N_MACROS}macros", us,
                     f"eff_tops={s['effective_tops']};util={s['avg_util']};"
                     f"energy_uj={s['energy_uj']};area_mm2={s['area_mm2']}"))

    # ---- batched cross-scenario co-design ----------------------------------
    workloads = {a: gemm_inventory(get_config(a)) for a in list_archs()}
    ppas = candidate_designs(tech)

    def scalar_codesign():
        return [[accelerator_report(g, p, n_macros=N_MACROS)
                 for p in ppas] for g in workloads.values()]

    _, us_scalar = timed(scalar_codesign, warmup=0, iters=1)
    report, us_batched = timed(
        lambda: cross_workload_codesign(workloads, ppas, n_macros=N_MACROS),
        iters=1)
    s = report.summary()
    rows.append((f"dse/codesign/{len(workloads)}x{len(ppas)}", us_batched,
                 f"frontier={len(report.frontier)};"
                 f"wall_spread={s['wallclock_spread']:.3f};"
                 f"energy_spread={s['energy_spread']:.3f}"))
    rows.append(("dse/codesign_speedup", us_batched,
                 f"speedup={us_scalar / us_batched:.2f}x;"
                 f"pairs={len(workloads) * len(ppas)}"))

    # MCR sensitivity on the MoE arch: higher MCR -> fewer weight reloads
    cfg = get_config("granite-moe-1b-a400m")
    gemms = gemm_inventory(cfg)
    for mcr in (1, 2, 4):
        spec = dataclasses.replace(reference_chip_design().spec, mcr=mcr)
        d = dataclasses.replace(reference_chip_design(), spec=spec)
        p = rollup(d, tech)
        rep, us = timed(lambda: accelerator_report(gemms, p, n_macros=64),
                        iters=1)
        reloads = sum(r.weight_reloads for r in rep.reports)
        rows.append((f"dse/moe_mcr{mcr}", us,
                     f"weight_reloads={reloads};"
                     f"cycles={rep.total_cycles}"))
    return rows
