"""Table II: the SynDCIM-generated test macro vs state-of-the-art DCIM
designs, under the paper's technology-scaling rules (scaled to 40nm, 1b-1b:
+80% area efficiency and +30% energy efficiency per node)."""

from __future__ import annotations


from repro.core import reference_chip_ppa

from .common import timed

# name: (node_nm, tops_scaled_already, tops_mm2, tops_w) — paper Table II rows
SOTA = {
    "ISSCC22_5nm": (5, 2.9, 104.0, 842.0),
    "ISSCC23_4nm": (4, 4.1, 64.3, 979.0),
    "ISSCC24_3nm": (3, 8.2, 98.0, 1090.0),
    "TCASI24_55nm": (55, 0.8, 22.67, 2848.0),
}

# process-node ladder for "per technology node" scaling steps
NODE_LADDER = [3, 4, 5, 7, 10, 16, 22, 28, 40, 55]


def _nodes_between(a: int, b: int) -> int:
    ia, ib = NODE_LADDER.index(a), NODE_LADDER.index(b)
    return ib - ia


def run() -> list[tuple]:
    def ours():
        p12 = reference_chip_ppa(1.2)
        p07 = reference_chip_ppa(0.7)
        return p12, p07

    (p12, p07), us = timed(ours, iters=1)
    rows = [
        ("table2/this_design", us,
         f"node=40nm;tops={p12.tops_1b:.1f};"
         f"tops_mm2={p12.tops_per_mm2_1b:.1f};"
         f"tops_w={p07.tops_per_w_1b['int_lo']:.0f};"
         f"area_mm2={p12.area_um2 / 1e6:.3f};macwrite=True"),
    ]
    for name, (node, tops, tmm2, tw) in SOTA.items():
        # Table II already scales competitors to 40nm/1b; report both raw and
        # the scaling factors used so the comparison is auditable.
        steps = _nodes_between(node, 40)
        area_k = 1.8 ** steps
        energy_k = 1.3 ** steps
        rows.append((f"table2/{name}", us,
                     f"node={node}nm;tops={tops};tops_mm2={tmm2};tops_w={tw};"
                     f"area_scale=1.8^{steps}={area_k:.2f};"
                     f"energy_scale=1.3^{steps}={energy_k:.2f}"))
    # headline: ours beats all on TOPS/W except the 55nm TCAS-I point, and is
    # competitive on TOPS/mm2 (80.5 vs 104/98)
    rows.append(("table2/headline", us,
                 f"ours_tops_w={p07.tops_per_w_1b['int_lo']:.0f}"
                 f";best_other=1090;ours_tops_mm2={p12.tops_per_mm2_1b:.1f}"))
    return rows
