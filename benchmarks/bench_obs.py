"""Observability cost + span-census benchmark for :mod:`repro.obs`.

Two questions, both asserted in CI's bench.json:

  ``obs/spans_per_request``   the deterministic span census: a cache-cold
                              stream of N unique requests through the
                              admission frontend must record *exactly*
                              ``6N + 4`` spans (per request: request,
                              request.queued, request.batched, cache.mem,
                              request.engine; per fused batch: engine.pass
                              + plan/place/execute; per unique miss:
                              engine.extract) — ``exact=True`` is the CI
                              gate, so a silently added or dropped
                              instrumentation point fails the build;
  ``obs/trace_overhead_pct``  tracing cost as a fraction of the p50
                              request latency.  E2e wall-clock diffs are
                              noise-dominated at this scale, so the
                              overhead is microbenchmark-derived: measured
                              per-span record cost x spans-per-request /
                              the measured p50 request latency.  CI
                              asserts ``on_pct <= 5`` (full sampling) and
                              ``off_pct <= 1`` (tracing disabled — the
                              noop-span fast path).

With ``OBS_TRACE_OUT=PATH`` in the environment the census run's spans are
also exported as a Chrome-trace JSON (CI uploads it as an artifact, so
every build carries a Perfetto-loadable serving timeline).
"""

from __future__ import annotations

import os
import time

from repro.core import calibrated_tech_for_reference
from repro.core.shardspec import spec_variants
from repro.obs import configure, tracer, write_chrome_trace
from repro.service import ServiceFrontend, SynthesisRequest, SynthesisService

N_UNIQUE = 4           # distinct specs in the cache-cold stream
GRID_RESOLUTION = 3
SPAN_ITERS = 20_000    # span-cost microbenchmark repetitions


def _serve_stream(uniques, tech):
    """One cache-cold pass of the stream through a deterministic frontend
    (no scheduler thread: ``run_pending`` drains one batch per call, and
    ``max_batch >= N`` makes it exactly one fused pass)."""
    svc = SynthesisService(tech=tech, resolution=GRID_RESOLUTION)
    front = ServiceFrontend(svc, max_batch=2 * len(uniques), start=False)
    tickets = [front.submit(SynthesisRequest(spec=s)) for s in uniques]
    while front.run_pending():
        pass
    responses = [t.result(timeout=600) for t in tickets]
    front.close()
    return responses


def _span_cost_s() -> float:
    """Per-span create+finish cost on the current tracer posture."""
    t0 = time.perf_counter()
    for _ in range(SPAN_ITERS):
        with tracer.span("bench.span"):
            pass
    return (time.perf_counter() - t0) / SPAN_ITERS


def run() -> list[tuple]:
    tech = calibrated_tech_for_reference()
    uniques = spec_variants(N_UNIQUE, seed=0)

    # Tracing OFF: the baseline p50 request latency (first pass warms the
    # jit caches so the measured pass times serving, not XLA compiles) and
    # the noop-span fast-path cost.
    configure(enabled=False)
    tracer.clear()
    _serve_stream(uniques, tech)
    responses = _serve_stream(uniques, tech)
    lats = sorted(r.latency_s for r in responses)
    p50_s = lats[len(lats) // 2]
    cost_off_s = _span_cost_s()

    # Tracing ON at full sampling: the deterministic span census.
    configure(enabled=True, sample=1.0)
    tracer.clear()
    _serve_stream(uniques, tech)
    spans = tracer.drain()
    expected = 6 * N_UNIQUE + 4
    n_spans = len(spans)
    per_request = n_spans / N_UNIQUE

    out = os.environ.get("OBS_TRACE_OUT")
    if out:
        write_chrome_trace(spans, out)

    # Per-span record cost under a live trace root.
    with tracer.start_trace("bench.root"):
        cost_on_s = _span_cost_s()
    tracer.clear()
    configure(enabled=False)

    on_pct = 100.0 * per_request * cost_on_s / p50_s
    off_pct = 100.0 * per_request * cost_off_s / p50_s

    return [
        ("obs/spans_per_request", cost_on_s * 1e6,
         f"per_request={per_request:.1f};spans={n_spans};"
         f"expected={expected};exact={n_spans == expected};"
         f"requests={N_UNIQUE}"),
        ("obs/trace_overhead_pct", cost_on_s * 1e6,
         f"on_pct={on_pct:.4f};off_pct={off_pct:.4f};"
         f"p50_ms={p50_s * 1e3:.2f};span_ns_on={cost_on_s * 1e9:.0f};"
         f"span_ns_off={cost_off_s * 1e9:.0f}"),
    ]
