"""Table I: compiler feature matrix — SynDCIM vs emerging DCIM compilers.
Ours is checked by *executing* each feature, not by assertion."""

from __future__ import annotations

import dataclasses

from repro.core import (SubcircuitLibrary, calibrated_tech_for_reference,
                        emit_verilog, mso_search, pareto_experiment_spec,
                        reference_chip_ppa)

from .common import timed


def run() -> list[tuple]:
    tech = calibrated_tech_for_reference()
    scl = SubcircuitLibrary(tech).build()

    def check():
        # end-to-end generation: spec -> searched design -> RTL
        res = mso_search(pareto_experiment_spec(), scl, tech)
        rtl = emit_verilog(res.frontier[0])
        e2e = "dcim_macro" in rtl
        # FP & INT support
        spec = dataclasses.replace(pareto_experiment_spec(),
                                   fp_precisions=("FP4", "FP8"))
        fpint = bool(reference_chip_ppa().e_cycle_fj.get("FP8"))
        # PPA-selectable subcircuits: frontier spans distinct subcircuit picks
        names = {p.design.name() for p in res.frontier}
        ppa_sel = len(names) >= 2
        # spec-oriented synthesis: all frontier designs meet the input spec
        spec_oriented = all(p.meets_timing for p in res.frontier)
        return e2e, fpint, ppa_sel, spec_oriented

    (e2e, fpint, ppa_sel, so), us = timed(check, iters=1)
    rows = [("table1/SynDCIM(ours)", us,
             f"end_to_end={e2e};fp_int={fpint};ppa_selectable={ppa_sel};"
             f"spec_oriented={so}")]
    for name, feat in {
        "AutoDCIM": "end_to_end=True;fp_int=False;ppa_selectable=False;spec_oriented=False",
        "EasyACIM(analog)": "end_to_end=True;fp_int=False;ppa_selectable=False;spec_oriented=True",
        "ISLPED23": "end_to_end=True;fp_int=False;ppa_selectable=False;spec_oriented=False",
        "ARCTIC": "end_to_end=True;fp_int=True;ppa_selectable=False;spec_oriented=False",
    }.items():
        rows.append((f"table1/{name}", 0.0, feat))
    return rows
