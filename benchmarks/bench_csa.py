"""Fig. 4/5: the mixed compressor/FA CSA design space — delay vs power vs
area across the rho family, with reorder/retime/split options, plus
functional verification of the synthesized netlists (gate-level sim)."""

from __future__ import annotations

import numpy as np

from repro.core import (CSADesign, build_netlist, calibrated_tech_for_reference,
                        characterize, verify_tree)

from .common import timed


def run() -> list[tuple]:
    tech = calibrated_tech_for_reference()
    rows = []
    for rho in (1.0, 0.75, 0.5, 0.25, 0.0):
        for ro in (False, True):
            d = CSADesign(rho=rho, reorder=ro, retimed=True)
            rep, us = timed(lambda d=d: characterize(d, 64, 2, tech))
            rows.append((f"csa/{d.name()}", us,
                         f"crit_tau={rep.crit_path_rel:.1f};"
                         f"energy={rep.energy_rel:.0f};"
                         f"area_um2={rep.area_um2:.0f};"
                         f"stages={rep.stages}"))
    # gate-level functional verification of the family
    def verify():
        rng = np.random.default_rng(0)
        ok = True
        for rho in (1.0, 0.5, 0.0):
            nl = build_netlist(CSADesign(rho=rho), 64)
            ops = rng.integers(-2**24, 2**24, size=(64, 64))
            ok &= verify_tree(nl, ops)
        return ok

    ok, us = timed(verify, iters=1)
    rows.append(("csa/gatesim_verify", us, f"all_sums_exact={ok}"))
    return rows
