"""Benchmark orchestrator: one module per paper table/figure + kernels, DSE
and the roofline reader.  Prints ``name,us_per_call,derived`` CSV."""

from __future__ import annotations

import sys
import traceback

from . import (bench_csa, bench_dse, bench_fig7_energy, bench_fig8_pareto,
               bench_fig9_shmoo, bench_kernels, bench_roofline,
               bench_table1_features, bench_table2_sota)
from .common import emit

MODULES = [
    ("fig7", bench_fig7_energy),
    ("fig8", bench_fig8_pareto),
    ("fig9", bench_fig9_shmoo),
    ("table1", bench_table1_features),
    ("table2", bench_table2_sota),
    ("csa", bench_csa),
    ("kernels", bench_kernels),
    ("dse", bench_dse),
    ("roofline", bench_roofline),
]


def main() -> None:
    print("name,us_per_call,derived")
    failed = []
    for name, mod in MODULES:
        try:
            emit(mod.run())
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
