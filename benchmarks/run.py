"""Benchmark orchestrator: one module per paper table/figure + kernels, DSE
and the roofline reader.  Prints ``name,us_per_call,derived`` CSV and, with
``--json <path>``, writes machine-readable rows for CI perf artifacts."""

from __future__ import annotations

import argparse
import json
import platform
import sys
import time
import traceback

from . import (bench_csa, bench_dse, bench_fig7_energy, bench_fig8_pareto,
               bench_fig9_shmoo, bench_frontend, bench_kernels,
               bench_lattice, bench_multispec, bench_obs, bench_pareto,
               bench_roofline, bench_service, bench_shardspec,
               bench_table1_features, bench_table2_sota)
from .common import emit, rows_to_dicts

MODULES = [
    ("fig7", bench_fig7_energy),
    ("fig8", bench_fig8_pareto),
    ("fig9", bench_fig9_shmoo),
    ("table1", bench_table1_features),
    ("table2", bench_table2_sota),
    ("csa", bench_csa),
    ("kernels", bench_kernels),
    ("dse", bench_dse),
    ("multispec", bench_multispec),
    ("shardspec", bench_shardspec),
    ("pareto", bench_pareto),
    ("lattice", bench_lattice),
    ("service", bench_service),
    ("frontend", bench_frontend),
    ("obs", bench_obs),
    ("roofline", bench_roofline),
]


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None,
                    help="comma-separated module subset (e.g. fig8,dse) — "
                         "used by the CI smoke job")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a JSON perf artifact")
    args = ap.parse_args(argv)

    selected = MODULES
    if args.only:
        wanted = [s.strip() for s in args.only.split(",") if s.strip()]
        unknown = [w for w in wanted if w not in {n for n, _ in MODULES}]
        if unknown:
            ap.error(f"unknown benchmark module(s): {unknown}; "
                     f"available: {[n for n, _ in MODULES]}")
        selected = [(n, m) for n, m in MODULES if n in wanted]

    print("name,us_per_call,derived")
    failed = []
    all_rows: list[dict] = []
    for name, mod in selected:
        try:
            rows = mod.run()
            emit(rows)
            all_rows.extend(rows_to_dicts(name, rows))
        except Exception:
            failed.append(name)
            traceback.print_exc()

    if args.json:
        artifact = {
            "schema": "syndcim-bench/v1",
            "unix_time": time.time(),
            "platform": platform.platform(),
            "python": platform.python_version(),
            "modules": [n for n, _ in selected],
            "failed": failed,
            "rows": all_rows,
        }
        with open(args.json, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"# wrote {len(all_rows)} rows to {args.json}", file=sys.stderr)

    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
