"""Fig. 9: shmoo plot of the silicon-validated macro — frequency/voltage
pass region, peaking at 1.1 GHz @ 1.2 V and 300 MHz @ 0.7 V (9 TOPS)."""

from __future__ import annotations


from repro.core import reference_chip_ppa

from .common import timed

VOLTAGES = (0.7, 0.8, 0.9, 1.0, 1.1, 1.2)
FREQS_MHZ = (100, 200, 300, 400, 500, 600, 700, 800, 900, 1000, 1100)


def run() -> list[tuple]:
    rows = []

    def shmoo():
        grid = {}
        for v in VOLTAGES:
            fmax = reference_chip_ppa(vdd=v).fmax_hz / 1e6
            grid[v] = [("P" if f <= fmax else ".") for f in FREQS_MHZ]
        return grid

    grid, us = timed(shmoo, iters=1)
    for v in VOLTAGES:
        rows.append((f"fig9/shmoo/{v:.1f}V", us, "".join(grid[v])))
    p12 = reference_chip_ppa(1.2)
    p07 = reference_chip_ppa(0.7)
    rows.append(("fig9/anchors", us,
                 f"fmax@1.2V={p12.fmax_hz / 1e6:.0f}MHz;"
                 f"tops={p12.tops_1b:.2f};"
                 f"fmax@0.7V={p07.fmax_hz / 1e6:.0f}MHz"))
    return rows
