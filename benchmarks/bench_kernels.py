"""Kernel microbenchmarks: XLA dispatch path wall-time on this host (CPU) +
tracked exactness rows for every Pallas execution style (interpret mode)
against the oracles, + an end-to-end autotune row.

On TPU the same entry points dispatch to the compiled Pallas kernels; CPU
numbers here are for harness regression tracking, not roofline claims.  The
``identical=``/``max_err=`` metrics ARE contract rows: CI asserts them, so a
pipelining or tiling change that breaks bit-exactness fails the smoke job,
not just the (slower) test tier.  ``autotune/picked_nondefault`` proves the
tuner end-to-end: on an M=64 shape the feasibility-pruned lattice excludes
the default bm=128 block, so the winner is deterministically non-default,
and the row also round-trips the winner through a scratch ArtifactRegistry.
"""

from __future__ import annotations

import tempfile

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels.csa_tree import csa_tree_pallas, csa_tree_ref, csa_tree_sum
from repro.kernels.dcim_mac import (dcim_matmul, dcim_matmul_int_pallas,
                                    dcim_matmul_int_pipelined_pallas)
from repro.kernels.dcim_mac import ref as mac_ref
from repro.kernels.ssm_scan import (ssm_scan_pallas, ssm_scan_pipelined_pallas,
                                    ssm_scan_ref)
from repro.kernels.tiles import DEFAULT_TILES
from repro.service.registry import ArtifactRegistry

from .common import timed

RNG = np.random.default_rng(0)


def _mac_rows() -> list[tuple]:
    rows = []
    # XLA dispatch-path wall time (the off-TPU serving path).
    for m, k, n in ((256, 512, 512), (512, 2048, 2048)):
        a = jnp.asarray(RNG.integers(-128, 128, (m, k)), jnp.int8)
        w = jnp.asarray(RNG.integers(-128, 128, (k, n)), jnp.int8)
        f = jax.jit(lambda a, w: dcim_matmul(a, w, 0.02, 0.01,
                                             use_pallas=False))
        out, us = timed(lambda: jax.block_until_ready(f(a, w)), iters=5)
        macs = m * k * n
        rows.append((f"kernel/dcim_mac/{m}x{k}x{n}", us,
                     f"gmacs_s={macs / us / 1e3:.2f}"))
    # Grid kernel vs the bit-serial DCIM oracle (the paper-faithful model).
    a = jnp.asarray(RNG.integers(-8, 8, (64, 128)), jnp.int8)
    w = jnp.asarray(RNG.integers(-8, 8, (128, 64)), jnp.int8)
    mxu = dcim_matmul_int_pallas(a, w, interpret=True)
    bits = mac_ref.dcim_matmul_bitserial_ref(a, w, 4, 4)
    rows.append(("kernel/dcim_mac/int_identical", 0.0,
                 f"identical={bool((np.asarray(mxu) == np.asarray(bits)).all())}"))
    # Multi-buffered DMA pipeline vs the XLA oracle on a ragged shape (pads
    # every dim) at both tuned depths.
    a = jnp.asarray(RNG.integers(-8, 8, (100, 300)), jnp.int8)
    w = jnp.asarray(RNG.integers(-8, 8, (300, 200)), jnp.int8)
    want = np.asarray(mac_ref.dcim_matmul_int_ref(a, w))
    same = all(
        (np.asarray(dcim_matmul_int_pipelined_pallas(
            a, w, depth=depth, interpret=True)) == want).all()
        for depth in (2, 4))
    rows.append(("kernel/dcim_mac/pipelined_identical", 0.0,
                 f"identical={same};depths=2|4"))
    return rows


def _csa_rows() -> list[tuple]:
    rows = []
    x = jnp.asarray(RNG.integers(-2**20, 2**20, (64, 512)), jnp.int32)
    out, us = timed(lambda: jax.block_until_ready(
        csa_tree_pallas(x, interpret=True)), iters=1)
    same = bool((np.asarray(out) == np.asarray(csa_tree_ref(x))).all())
    rows.append(("kernel/csa_tree/identical", us, f"identical={same}"))
    # Tiled-H variant above the whole-rows limit (H=600 > 512), reached
    # through the public entry point's automatic routing.
    x = jnp.asarray(RNG.integers(-2**20, 2**20, (600, 256)), jnp.int32)
    out, us = timed(lambda: jax.block_until_ready(
        csa_tree_sum(x, use_pallas=True, interpret=True)), iters=1)
    same = bool((np.asarray(out) == np.asarray(csa_tree_ref(x))).all())
    rows.append(("kernel/csa_tree/tiled_identical", us,
                 f"identical={same};h=600"))
    return rows


def _ssm_rows() -> list[tuple]:
    rows = []
    t, d = 1024, 256
    aa = jnp.asarray(RNG.uniform(0.8, 1.0, (t, d)), jnp.float32)
    bb = jnp.asarray(RNG.normal(size=(t, d)), jnp.float32)
    h0 = jnp.zeros((d,), jnp.float32)
    ref = jax.jit(lambda a, b, h: ssm_scan_ref(a, b, h))
    out, us = timed(lambda: jax.block_until_ready(ref(aa, bb, h0)), iters=3)
    s_grid, _ = ssm_scan_pallas(aa, bb, h0, interpret=True)
    err = float(jnp.abs(s_grid - out[0]).max())
    rows.append((f"kernel/ssm_scan/{t}x{d}", us, f"max_err={err:.1e}"))
    s_pipe, _ = ssm_scan_pipelined_pallas(aa, bb, h0, depth=2, interpret=True)
    err = float(jnp.abs(s_pipe - out[0]).max())
    rows.append(("kernel/ssm_scan/pipelined", 0.0, f"max_err={err:.1e}"))
    return rows


def _autotune_row() -> tuple:
    # M=64 prunes the default bm=128 from the lattice -> the winner is
    # non-default by construction, independent of timing noise.
    shape = (64, 128, 128)
    with tempfile.TemporaryDirectory() as root:
        reg = ArtifactRegistry(root)
        res, us = timed(lambda: autotune.autotune(
            "dcim_mac", shape, iters=1, registry=reg, memoize=False),
            warmup=0, iters=1)
        autotune.clear_memo()
        got = autotune.lookup("dcim_mac", shape, registry=reg)
        roundtrip = (got == res.winner
                     and got != DEFAULT_TILES["dcim_mac"])
    return ("autotune/picked_nondefault", us,
            f"picked_nondefault={res.picked_nondefault};"
            f"registry_roundtrip={roundtrip};"
            f"winner_bm={res.winner.bm};candidates={len(res.candidates)}")


def run() -> list[tuple]:
    return _mac_rows() + _csa_rows() + _ssm_rows() + [_autotune_row()]
