"""Kernel microbenchmarks: XLA dispatch path wall-time on this host (CPU) +
bit-exactness of the Pallas path (interpret mode) against the oracles.

On TPU the same entry points dispatch to the compiled Pallas kernels; CPU
numbers here are for harness regression tracking, not roofline claims."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.csa_tree import csa_tree_pallas, csa_tree_ref
from repro.kernels.dcim_mac import dcim_matmul, dcim_matmul_int_pallas
from repro.kernels.dcim_mac import ref as mac_ref
from repro.kernels.ssm_scan import ssm_scan_pallas, ssm_scan_ref

from .common import timed

RNG = np.random.default_rng(0)


def run() -> list[tuple]:
    rows = []
    # dcim_mac XLA path
    for m, k, n in ((256, 512, 512), (512, 2048, 2048)):
        a = jnp.asarray(RNG.integers(-128, 128, (m, k)), jnp.int8)
        w = jnp.asarray(RNG.integers(-128, 128, (k, n)), jnp.int8)
        f = jax.jit(lambda a, w: dcim_matmul(a, w, 0.02, 0.01,
                                             use_pallas=False))
        out, us = timed(lambda: jax.block_until_ready(f(a, w)), iters=5)
        macs = m * k * n
        rows.append((f"kernel/dcim_mac/{m}x{k}x{n}", us,
                     f"gmacs_s={macs / us / 1e3:.2f}"))
    # bit-exactness of the Pallas path
    a = jnp.asarray(RNG.integers(-8, 8, (64, 128)), jnp.int8)
    w = jnp.asarray(RNG.integers(-8, 8, (128, 64)), jnp.int8)
    mxu = dcim_matmul_int_pallas(a, w, interpret=True)
    bits = mac_ref.dcim_matmul_bitserial_ref(a, w, 4, 4)
    rows.append(("kernel/dcim_mac/bit_exact_vs_dcim", 0.0,
                 f"equal={bool((np.asarray(mxu) == np.asarray(bits)).all())}"))
    # csa_tree
    x = jnp.asarray(RNG.integers(-2**20, 2**20, (64, 512)), jnp.int32)
    out, us = timed(lambda: jax.block_until_ready(
        csa_tree_pallas(x, interpret=True)), iters=1)
    rows.append(("kernel/csa_tree/64x512", us,
                 f"exact={bool((np.asarray(out) == np.asarray(csa_tree_ref(x))).all())}"))
    # ssm_scan
    t, d = 1024, 256
    aa = jnp.asarray(RNG.uniform(0.8, 1.0, (t, d)), jnp.float32)
    bb = jnp.asarray(RNG.normal(size=(t, d)), jnp.float32)
    h0 = jnp.zeros((d,), jnp.float32)
    ref = jax.jit(lambda a, b, h: ssm_scan_ref(a, b, h))
    out, us = timed(lambda: jax.block_until_ready(ref(aa, bb, h0)), iters=3)
    s_pl, _ = ssm_scan_pallas(aa, bb, h0, interpret=True)
    err = float(jnp.abs(s_pl - out[0]).max())
    rows.append((f"kernel/ssm_scan/{t}x{d}", us, f"pallas_max_err={err:.1e}"))
    return rows
