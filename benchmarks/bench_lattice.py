"""Axis-generic lattice: incremental re-synthesis vs re-rolling the full
product, plus the registry's axis scale-up headroom.

The incremental scenario is the one the per-axis cache keys were built for:
a sweep is served cold (seeding the per-axis slice caches), then a single
axis changes — here the rho axis grows by one step, the "try one more
compression ratio" recalibration — and the service re-evaluates ONLY the
invalidated sublattice, merging it with the cached slice frontiers.

Tracked rows (asserted present in CI's bench.json):

  ``lattice/incremental_speedup``   cold full-product pass vs incremental
                                    merge on the same changed input —
                                    required >= 5x by the acceptance bar,
                                    and carries ``identical=`` (the merged
                                    frontier must be bit-identical to the
                                    cold pass's);
  ``lattice/axis_scaleup_points``   the full registered axis product
                                    (precision modes x approximate adder
                                    cells x seed axes) enumerated through
                                    the same registry the seed axes use.
"""

from __future__ import annotations

import dataclasses

from repro.core import calibrated_tech_for_reference
from repro.core import subcircuits as sc
from repro.core.axes import LatticeConfig
from repro.core.batched import DesignLattice
from repro.core.macro import MacroSpec
from repro.service import FrontierCache, SynthesisRequest, SynthesisService

from .common import timed

#: Base config for the incremental scenario: one memcell keeps the cold
#: pass inside bench-smoke budget; three precision modes scale the lattice
#: so kernel evaluation (the part incrementality saves) dominates.
BASE = LatticeConfig(memcells=(sc.MemCellKind.SRAM_6T,), precision_modes=3)


def _sweep(svc: SynthesisService, spec, tech, config):
    (resp,) = svc.serve([SynthesisRequest(spec=spec, tech=tech,
                                          kind="sweep", config=config)])
    return resp.result


def run() -> list[tuple]:
    tech = calibrated_tech_for_reference()
    spec = MacroSpec()
    grown = dataclasses.replace(BASE, rho_steps=BASE.rho_steps + (0.9,))

    # Warm service: cold sweep on BASE seeds the per-axis slice caches;
    # the grown-axis request then reuses every unchanged rho slice.
    warm_svc = SynthesisService(tech=tech, config=BASE)
    _sweep(warm_svc, spec, tech, BASE)
    incremental, us_inc = timed(
        lambda: _sweep(warm_svc, spec, tech, grown), warmup=0, iters=1)

    # Cold baseline: a fresh service re-rolls the full grown product.
    def cold_pass():
        svc = SynthesisService(cache=FrontierCache(), tech=tech, config=BASE)
        return _sweep(svc, spec, tech, grown)

    cold, us_cold = timed(cold_pass, warmup=0, iters=1)

    identical = dataclasses.asdict(incremental) == dataclasses.asdict(cold)
    s = warm_svc.stats
    n_grown = len(DesignLattice.enumerate(spec, config=grown))
    reused = len(BASE.rho_steps)

    # Axis scale-up: the full registered product, enumerated (not evaluated)
    # through the same registry — the lattice the compiler can now address.
    full = LatticeConfig(precision_modes=3, approx_cells=sc.APPROX_CELLS)
    lat_full, us_enum = timed(
        lambda: DesignLattice.enumerate(spec, config=full), iters=3)
    n_seed = len(DesignLattice.enumerate(spec))

    return [
        (f"lattice/cold_sweep/{n_grown}pt", us_cold,
         f"points={n_grown};axes={len(grown.rho_steps)}rho"),
        (f"lattice/incremental_sweep/{n_grown}pt", us_inc,
         f"slice_hits={s.slice_hits};incremental_passes="
         f"{s.incremental_passes};reused_slices={reused}/{reused + 1}"),
        ("lattice/incremental_speedup", us_inc,
         f"speedup={us_cold / us_inc:.2f}x;identical={identical};"
         f"floor=5x;points={n_grown}"),
        ("lattice/axis_scaleup_points", us_enum,
         f"points={len(lat_full)};axes={len(lat_full.dims)};"
         f"vs_seed={len(lat_full) / n_seed:.0f}x"),
    ]
